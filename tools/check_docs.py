"""Docs CI: markdown link/path checker + TRAINING.md code-block smoke.

Two checks, selectable so the dep-free half can run in the lint job:

* ``--links-only`` — needs nothing installed.  Scans the repo's markdown
  (README.md, ROADMAP.md, docs/*.md) for

    - relative markdown links ``[text](path)`` (http(s)/mailto/#anchor
      links are skipped), resolved against the containing file, and
    - backticked repo paths like ``src/repro/core/fenix.py`` or
      ``tests/test_conformance.py`` (tokens matching a top-level repo
      directory + ``/`` + a file-ish tail),

  and fails on any that do not exist — so a refactor that moves a module
  breaks the docs job instead of silently rotting the docs.

* code-block smoke (the default, additionally) — executes every
  ```python block of docs/TRAINING.md in order in ONE shared namespace
  (so later blocks can use earlier blocks' variables, exactly as a
  reader would run them), with ``src/`` on the path.  Blocks whose first
  line starts with ``# not executed in CI`` are compiled for syntax but
  not run (real-corpus downloads, full-size training).  Needs jax — CI
  runs it in the docs job after installing requirements.
"""

from __future__ import annotations

import argparse
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = ["README.md", "ROADMAP.md"] + sorted(
    os.path.join("docs", f) for f in os.listdir(os.path.join(REPO, "docs"))
    if f.endswith(".md")) if os.path.isdir(os.path.join(REPO, "docs")) \
    else ["README.md", "ROADMAP.md"]

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `backticked` repo paths: a known top-level dir, then /, then a tail
# ending in a file extension (pure directory mentions are allowed)
TICKED_PATH = re.compile(
    r"`((?:src|tests|benchmarks|docs|examples|tools)/[\w\-./]+"
    r"\.(?:py|md|json|toml|yml|yaml|csv|pcap))`")

SKIP_SCHEMES = ("http://", "https://", "mailto:")


def check_links() -> list:
    errors = []
    for rel in DOC_FILES:
        path = os.path.join(REPO, rel)
        if not os.path.exists(path):
            continue
        text = open(path, encoding="utf-8").read()
        base = os.path.dirname(path)
        for m in MD_LINK.finditer(text):
            target = m.group(1)
            if target.startswith(SKIP_SCHEMES) or target.startswith("#"):
                continue
            if target.startswith("../../actions/"):
                continue                      # the CI badge, host-side
            target = target.split("#")[0]
            if not target:
                continue
            resolved = os.path.normpath(os.path.join(base, target))
            if not os.path.exists(resolved):
                errors.append(f"{rel}: dead link ({m.group(1)})")
        for m in TICKED_PATH.finditer(text):
            ticked = m.group(1)
            if ticked.startswith("benchmarks/results/") and \
                    not ticked.startswith("benchmarks/results/baseline/"):
                continue            # generated at runtime, not committed
            resolved = os.path.join(REPO, ticked)
            if not os.path.exists(resolved):
                errors.append(f"{rel}: dead path `{ticked}`")
    return errors


FENCE = re.compile(r"^```(\w*)\s*$")


def iter_code_blocks(md_path: str, lang: str = "python"):
    """Yields (start_line, source) for each ``lang`` fence in the file."""
    lines = open(md_path, encoding="utf-8").read().splitlines()
    i = 0
    while i < len(lines):
        m = FENCE.match(lines[i])
        if m and m.group(1) == lang:
            start = i + 1
            j = start
            while j < len(lines) and not lines[j].startswith("```"):
                j += 1
            yield start + 1, "\n".join(lines[start:j])
            i = j + 1
        else:
            i += 1


def run_blocks(md_rel: str = os.path.join("docs", "TRAINING.md")) -> list:
    md_path = os.path.join(REPO, md_rel)
    sys.path.insert(0, os.path.join(REPO, "src"))
    ns = {"__name__": "__docs__"}
    errors = []
    for lineno, src in iter_code_blocks(md_path):
        label = f"{md_rel}:{lineno}"
        try:
            code = compile(src, label, "exec")
        except SyntaxError as e:
            errors.append(f"{label}: syntax error: {e}")
            continue
        first = src.lstrip().splitlines()[0] if src.strip() else ""
        if first.startswith("# not executed in CI"):
            print(f"{label}: syntax-checked only ({first[2:].strip()})")
            continue
        print(f"{label}: executing...")
        try:
            exec(code, ns)
        except Exception as e:  # noqa: BLE001 — any failure fails the job
            errors.append(f"{label}: {type(e).__name__}: {e}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--links-only", action="store_true",
                    help="run only the dep-free link/path checker")
    args = ap.parse_args(argv)
    errors = check_links()
    n_files = len([f for f in DOC_FILES
                   if os.path.exists(os.path.join(REPO, f))])
    print(f"link check: {n_files} markdown files scanned, "
          f"{len(errors)} problems")
    if not args.links_only:
        errors += run_blocks()
    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
