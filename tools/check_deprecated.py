"""Deprecated-kwarg lint: no new in-repo uses of the pre-driver= API.

The ``FenixConfig(driver=...)`` redesign keeps the old boolean knobs
(``fast_mode``/``device_path``/``pipes_path``/``farm_path``) and
``run_trace``'s ``trace_labels=``/``labels_by_flow=`` working through a
deprecation shim — for downstream users, not for this repo.  This
dep-free checker greps every tracked ``.py`` file for the deprecated
spellings and fails on any hit outside the allowlist (the shim itself
and the suite that tests it), so the legacy surface can't creep back in
via copy-paste.

Run from anywhere: ``python tools/check_deprecated.py``.  Exit 0 clean,
1 with one ``path:line: text`` row per violation.
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# keyword-argument uses of the deprecated names: the `=` must be followed
# by a value so prose like ``trace_labels=, limit=`` in docstrings that
# *describe* the deprecated surface stays legal
PATTERN = re.compile(
    r"\b(fast_mode|device_path|pipes_path|farm_path"
    r"|trace_labels|labels_by_flow)\s*=\s*[^=,\s)]")

# the shim that implements the deprecated surface, the tests that pin
# it, and this checker's own docstring
ALLOWED = {
    os.path.join("src", "repro", "core", "fenix.py"),
    os.path.join("tests", "test_driver_api.py"),
    os.path.join("tools", "check_deprecated.py"),
}

SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "fixtures",
             "results", "node_modules", ".venv"}


def iter_py_files():
    for root, dirs, files in os.walk(REPO):
        dirs[:] = [d for d in dirs if d not in SKIP_DIRS]
        for f in files:
            if f.endswith(".py"):
                yield os.path.join(root, f)


def main() -> int:
    violations = []
    for path in sorted(iter_py_files()):
        rel = os.path.relpath(path, REPO)
        if rel in ALLOWED:
            continue
        with open(path, encoding="utf-8") as fh:
            for i, line in enumerate(fh, 1):
                if PATTERN.search(line):
                    violations.append(f"{rel}:{i}: {line.rstrip()}")
    if violations:
        print("deprecated pre-driver= kwargs found outside the shim "
              "(use FenixConfig(driver=...) / run_trace(trace=...)):",
              file=sys.stderr)
        for v in violations:
            print(f"  {v}", file=sys.stderr)
        return 1
    print(f"check_deprecated: clean ({len(ALLOWED)} allowlisted files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
