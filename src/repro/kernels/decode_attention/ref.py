"""Pure-jnp oracle for single-token GQA decode attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array) -> jax.Array:
    """q [B,Hq,D]; k,v [B,S,Hkv,D]; lengths [B] -> out [B,Hq,D]."""
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg.astype(F32), k.astype(F32))
    scores = scores * (d ** -0.5)
    mask = jnp.arange(s)[None, :] < lengths[:, None]
    scores = jnp.where(mask[:, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(F32))
    return out.reshape(b, hq, d).astype(v.dtype)
