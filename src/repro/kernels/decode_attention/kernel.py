"""Pallas TPU kernel: GQA decode attention with KV streamed HBM->VMEM.

Serving hot spot for every assigned LM arch (decode_32k / long_500k are
memory-bound on exactly this KV read).  Design:

  grid = (B, Hkv, S/ck) — KV chunks innermost so the online-softmax
  accumulators (m, l, acc) persist in VMEM scratch across the KV loop.

  q tile    (1, G, D)        resident (one batch row, one kv-head group)
  k/v tiles (1, ck, D)       streamed chunks of the cache
  scratch   m,l (G,), acc (G, D) fp32
  out       (1, G, D)        written at the last chunk

Chunk masking uses the per-row length from SMEM; fully-masked chunks cost
one skipped block (predicated write) — on real TPU the DMA is still issued,
so pick ck to balance VMEM vs bandwidth (1024 default: 2*ck*D*2B ~ 0.5MB
per head at D=128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, ck: int, n_chunks: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(F32)                     # [G, D]
    k = k_ref[0, 0].astype(F32)                     # [ck, D]
    v = v_ref[0, 0].astype(F32)                     # [ck, D]
    length = len_ref[b]
    kpos = j * ck + jax.lax.broadcasted_iota(jnp.int32, (1, ck), 1)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * scale  # [G, ck]
    s = jnp.where(kpos < length, s, -jnp.inf)
    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(jnp.isinf(s), 0.0, p)
    corr = jnp.exp(jnp.where(jnp.isinf(m_old), 0.0, m_old) - m_safe)
    corr = jnp.where(jnp.isinf(m_old), 0.0, corr)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32)

    @pl.when(j == n_chunks - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ck", "interpret"))
def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            lengths: jax.Array, ck: int = 1024,
                            interpret: bool = True) -> jax.Array:
    """q [B,Hq,D]; k,v [B,S,Hkv,D] (S % ck == 0); lengths [B] int32."""
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    assert s % ck == 0, (s, ck)
    g = hq // hkv
    n_chunks = s // ck
    qg = q.reshape(b, hkv, g, d)
    # layout [B, Hkv, S, D] so the kv chunk is the contiguous minor block
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    grid = (b, hkv, n_chunks)
    out = pl.pallas_call(
        functools.partial(_kernel, ck=ck, n_chunks=n_chunks,
                          scale=d ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),     # lengths, full
            pl.BlockSpec((1, 1, g, d), lambda bb, h, j: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, ck, d), lambda bb, h, j: (bb, h, j, 0)),
            pl.BlockSpec((1, 1, ck, d), lambda bb, h, j: (bb, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bb, h, j: (bb, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), v.dtype),
        scratch_shapes=[pltpu.VMEM((g,), F32), pltpu.VMEM((g,), F32),
                        pltpu.VMEM((g, d), F32)],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, kt, vt)
    return out.reshape(b, hq, d)
