"""jit'd wrapper for GQA decode attention: backend switch + padding."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref

_BACKEND = "ref"


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("ref", "pallas", "pallas_tpu")
    _BACKEND = name


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, ck: int = 1024,
                     backend: Optional[str] = None) -> jax.Array:
    """q [B,Hq,D]; k,v [B,S,Hkv,D]; lengths [B] -> [B,Hq,D]."""
    backend = backend or _BACKEND
    if backend == "ref":
        return decode_attention_ref(q, k, v, lengths)
    s = k.shape[1]
    ck = min(ck, s)
    pad = (-s) % ck
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return decode_attention_pallas(q, k, v, lengths, ck=ck,
                                   interpret=(backend == "pallas"))
