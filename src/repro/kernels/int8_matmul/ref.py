"""Pure-jnp oracle for the INT8 systolic GEMM (FENIX Model Engine §5.2).

Semantics: C = A(int8) @ B(int8) accumulated in int32, optionally
requantized to int8 by  clip((acc + bias) >> shift)  — power-of-two
fixed-point rescaling, matching the paper's "different decimal point
positions to different layers" quantization.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def int8_matmul_ref(a: jax.Array, b: jax.Array,
                    bias: Optional[jax.Array] = None,
                    shift: Optional[int] = None) -> jax.Array:
    """Reference INT8 GEMM — the numerics contract every backend matches.

      a      [M, K] int8     activations
      b      [K, N] int8     weights
      bias   [N]    int32    optional, added on the accumulator grid
                             2^(sa_in + sw) before requantization
      shift  int >= 0        optional pow2 requantization: round-half-up
                             ``(acc + (1 << (shift-1))) >> shift`` then
                             saturate to [-127, 127].  ``shift=0`` only
                             saturates; ``None`` skips requantization.

    Returns [M, N] int8 when ``shift`` is given, raw int32 accumulator
    otherwise.  Accumulation is exact (int32 never overflows for K <=
    2^15 at full-scale int8 inputs), so ``ops.int8_matmul(backend=
    "pallas")`` is asserted bit-identical to this function in
    tests/test_kernels.py and tests/test_quantize.py.
    """
    assert a.dtype == jnp.int8 and b.dtype == jnp.int8
    acc = jnp.dot(a.astype(jnp.int32), b.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    if bias is not None:
        acc = acc + bias.astype(jnp.int32)[None, :]
    if shift is None:
        return acc
    # rounding shift (round-half-up in fixed point), then saturate to int8
    rounded = (acc + (1 << (shift - 1))) >> shift if shift > 0 else acc
    return jnp.clip(rounded, -127, 127).astype(jnp.int8)
