"""Pallas TPU kernel: INT8 GEMM with int32 accumulation + pow2 requant.

The FENIX Model Engine's "Neural Computing Array" is a systolic array for
INT8 matrix ops (§5.2).  The TPU MXU *is* a 128x128 systolic array with
native int8 multipliers, so the mapping is direct:

  grid = (M/bm, N/bn, K/bk), K innermost so the int32 accumulator tile
  lives in VMEM scratch across the K loop (revisiting pattern).

  A tile (bm, bk) int8   - VMEM, streamed along K
  B tile (bk, bn) int8   - VMEM, streamed along K
  acc  (bm, bn) int32    - VMEM scratch, zeroed at k==0
  out  (bm, bn)          - written at k==K-1, optionally requantized by
                           (acc + bias) >> shift -> int8 (bias tile (1,bn))

Block shapes default to MXU-aligned 128 multiples (int8 wants (32,128)
minimum tiles; 128/256 chosen for >=50% MXU utilization at small M).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

I32 = jnp.int32


def _kernel(a_ref, b_ref, bias_ref, out_ref, acc_ref, *, n_k: int,
            shift: Optional[int], out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=I32)

    @pl.when(k == n_k - 1)
    def _write():
        acc = acc_ref[...]
        if bias_ref is not None:
            acc = acc + bias_ref[...].astype(I32)
        if shift is None:
            out_ref[...] = acc.astype(out_dtype)
        else:
            if shift > 0:
                acc = (acc + (1 << (shift - 1))) >> shift
            out_ref[...] = jnp.clip(acc, -127, 127).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "shift",
                                             "interpret"))
def int8_matmul_pallas(a: jax.Array, b: jax.Array,
                       bias: Optional[jax.Array] = None,
                       shift: Optional[int] = None,
                       bm: int = 128, bn: int = 128, bk: int = 128,
                       interpret: bool = True) -> jax.Array:
    """Blocked INT8 GEMM on the MXU: same contract as ``int8_matmul_ref``.

      a     [M, K] int8, b [K, N] int8 — M, N, K must be multiples of
            the block shapes (bm, bn, bk); callers go through
            ``ops.int8_matmul``, which pads arbitrary shapes up to the
            blocks and slices the result back.
      bias  [N] int32 on the accumulator grid, shift the pow2
            requantization (round-half-up, saturate to [-127, 127]) —
            see :func:`~repro.kernels.int8_matmul.ref.int8_matmul_ref`
            for the full quant-scale contract.

    Returns [M, N] int8 when ``shift`` is given, int32 otherwise.
    ``interpret=True`` (the ``"pallas"`` backend) runs the kernel body
    through the Pallas interpreter on CPU — bit-identical, usable inside
    jitted scans/shard_map; ``interpret=False`` (``"pallas_tpu"``)
    compiles for a real TPU MXU.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (a.shape, b.shape, bm, bn, bk)
    out_dtype = jnp.int8 if shift is not None else I32
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    args = [a, b]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        args.append(bias.reshape(1, n).astype(I32))
        kern = functools.partial(_kernel, n_k=n_k, shift=shift,
                                 out_dtype=out_dtype)
    else:
        def kern(a_ref, b_ref, out_ref, acc_ref):
            return _kernel(a_ref, b_ref, None, out_ref, acc_ref, n_k=n_k,
                           shift=shift, out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), I32)],
        interpret=interpret,
    )(*args)
