"""jit'd public wrapper for the INT8 GEMM: padding, backend switch, vmap.

``int8_matmul(a, b, ...)`` pads M/N/K up to block multiples, dispatches to
the Pallas kernel (interpret=True on CPU, compiled on real TPU) or the
pure-jnp reference (the default for CPU simulation speed), and slices the
result back.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.int8_matmul.kernel import int8_matmul_pallas
from repro.kernels.int8_matmul.ref import int8_matmul_ref

_BACKEND = "ref"  # "ref" | "pallas" | "pallas_tpu"


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("ref", "pallas", "pallas_tpu")
    _BACKEND = name


def _pad(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def int8_matmul(a: jax.Array, b: jax.Array,
                bias: Optional[jax.Array] = None,
                shift: Optional[int] = None,
                backend: Optional[str] = None) -> jax.Array:
    """a [M,K] int8 @ b [K,N] int8 -> [M,N] int32 (int8 when shift given)."""
    backend = backend or _BACKEND
    m, k = a.shape
    _, n = b.shape
    if backend == "ref":
        return int8_matmul_ref(a, b, bias=bias, shift=shift)
    bm = bn = bk = 128
    ap = _pad(a, bm, bk)
    bp = _pad(b, bk, bn)
    biasp = None
    if bias is not None:
        biasp = jnp.pad(bias, (0, (-n) % bn))
    out = int8_matmul_pallas(ap, bp, bias=biasp, shift=shift,
                             bm=bm, bn=bn, bk=bk,
                             interpret=(backend == "pallas"))
    return out[:m, :n]


def int8_conv1d(x: jax.Array, w: jax.Array, bias: Optional[jax.Array],
                shift: Optional[int], backend: Optional[str] = None
                ) -> jax.Array:
    """Causal-free 'same' conv1d as im2col onto the systolic GEMM.

    x [B,S,Cin] int8, w [K,Cin,Cout] int8 -> [B,S,Cout].
    The paper runs Conv layers on the same systolic array as FC (§5.2) —
    im2col is exactly that mapping.
    """
    bsz, s, cin = x.shape
    kk, _, cout = w.shape
    pad = kk // 2
    xp = jnp.pad(x, ((0, 0), (pad, kk - 1 - pad), (0, 0)))
    cols = jnp.stack([xp[:, i:i + s] for i in range(kk)], axis=2)
    a = cols.reshape(bsz * s, kk * cin)
    bmat = w.reshape(kk * cin, cout)
    y = int8_matmul(a, bmat, bias=bias, shift=shift, backend=backend)
    return y.reshape(bsz, s, cout)
