"""Public entry points for the INT8 systolic GEMM: padding, backend
dispatch, conv-as-GEMM.

This is the Model Engine's matmul surface (§5.2): every dense layer and
every conv layer of the quantized traffic models lowers onto one of the
two functions here, selected by a single ``backend`` knob that
``FenixConfig(matmul_backend=...)`` threads through the serving loop the
same way ``gate_backend`` selects the admission kernel:

  ``"ref"``         pure-jnp oracle (``ref.int8_matmul_ref``) — default;
                    fastest on CPU, the numerics contract the Pallas
                    kernel must match bit-for-bit.
  ``"pallas"``      the Pallas kernel in interpret mode — runs anywhere,
                    asserted bit-identical to ``"ref"``
                    (tests/test_quantize.py, tests/test_conformance.py).
  ``"pallas_tpu"``  the same kernel compiled for a real TPU MXU.

Shape/dtype contract (shared by every backend): inputs are int8, the
accumulator is int32, and requantization is a power-of-two right shift —
see :func:`int8_matmul`.  The wrappers pad M/N/K up to the 128-multiple
block shapes the kernel wants and slice the result back, so callers never
see the padding.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.int8_matmul.kernel import int8_matmul_pallas
from repro.kernels.int8_matmul.ref import int8_matmul_ref

MATMUL_BACKENDS = ("ref", "pallas", "pallas_tpu")

_BACKEND = "ref"


def validate_backend(name: str) -> str:
    """Check a matmul backend name; returns it (raises ValueError else)."""
    if name not in MATMUL_BACKENDS:
        raise ValueError(f"unknown matmul_backend {name!r}; "
                         f"expected one of {MATMUL_BACKENDS}")
    return name


def set_backend(name: str) -> None:
    """Set the process-wide default backend (overridden per call)."""
    global _BACKEND
    _BACKEND = validate_backend(name)


def _pad(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def int8_matmul(a: jax.Array, b: jax.Array,
                bias: Optional[jax.Array] = None,
                shift: Optional[int] = None,
                backend: Optional[str] = None) -> jax.Array:
    """INT8 GEMM with int32 accumulation and pow2 requantization.

    Contract (identical across backends, asserted bit-for-bit in tests):

      a      [M, K] int8      activations (rows are independent lanes;
                              zero-padded rows produce zero-padded rows)
      b      [K, N] int8      weights
      bias   [N]   int32      optional, on the accumulator grid
                              2^(sa_in + sw) (quant/quantize.py)
      shift  int >= 0         optional pow2 requantization: the int32
                              accumulator is rounded half-up by
                              ``(acc + (1 << (shift-1))) >> shift`` and
                              saturated to [-127, 127] int8.  ``None``
                              returns the raw int32 accumulator.

    Returns [M, N] — int8 when ``shift`` is given, int32 otherwise.
    ``backend`` overrides the process default (see module docstring); the
    Pallas backends pad M/N/K to 128-multiples internally and slice back.
    """
    backend = validate_backend(backend or _BACKEND)
    m, k = a.shape
    _, n = b.shape
    if backend == "ref":
        return int8_matmul_ref(a, b, bias=bias, shift=shift)
    bm = bn = bk = 128
    ap = _pad(a, bm, bk)
    bp = _pad(b, bk, bn)
    biasp = None
    if bias is not None:
        biasp = jnp.pad(bias, (0, (-n) % bn))
    out = int8_matmul_pallas(ap, bp, bias=biasp, shift=shift,
                             bm=bm, bn=bn, bk=bk,
                             interpret=(backend == "pallas"))
    return out[:m, :n]


def int8_conv1d(x: jax.Array, w: jax.Array, bias: Optional[jax.Array],
                shift: Optional[int], backend: Optional[str] = None
                ) -> jax.Array:
    """'same'-padded conv1d as im2col onto the systolic GEMM.

    The paper runs Conv layers on the same systolic array as FC layers
    (§5.2, "one systolic array, many layer types") — im2col is exactly
    that mapping: the K-tap window unrolls into the GEMM's contraction
    dimension and the conv becomes one :func:`int8_matmul` call.

      x      [B, S, Cin]    int8 activations
      w      [K, Cin, Cout] int8 filters (K odd -> symmetric 'same' pad)
      bias   [Cout] int32 / shift — same requantization contract as
                            :func:`int8_matmul`

    Returns [B, S, Cout] (int8 when ``shift`` is given, int32 otherwise).
    """
    bsz, s, cin = x.shape
    kk, _, cout = w.shape
    pad = kk // 2
    xp = jnp.pad(x, ((0, 0), (pad, kk - 1 - pad), (0, 0)))
    cols = jnp.stack([xp[:, i:i + s] for i in range(kk)], axis=2)
    a = cols.reshape(bsz * s, kk * cin)
    bmat = w.reshape(kk * cin, cout)
    y = int8_matmul(a, bmat, bias=bias, shift=shift, backend=backend)
    return y.reshape(bsz, s, cout)
