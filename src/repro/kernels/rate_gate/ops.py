"""jit'd wrappers for the rate gate: backend switch, padding, rand supply.

Two entry points:

* ``rate_gate`` — the legacy selection-only op (LUT lookup + threshold);
  kept as the unfused half for benchmarks and the kernel sweep tests.
* ``fused_admission`` — the fused op the Data Engine actually calls: LUT
  lookup + threshold + token-bucket credit check in ONE call, returning
  the grant mask and the updated bucket level.  ``backend="ref"`` is the
  pure-jnp oracle (bit-exact with the historical inline math); the pallas
  backends run the fused kernel (interpret on CPU, compiled + on-core
  PRNG on TPU).

In ``ref``/``pallas`` modes the caller supplies random bits (jax.random)
so results are bit-exact reproducible; in ``pallas_tpu`` mode the on-core
PRNG generates them.  The *selection* distribution is identical (uniform
16-bit threshold).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.rate_gate.kernel import fused_gate_pallas, rate_gate_pallas
from repro.kernels.rate_gate.ref import fused_admission_ref, rate_gate_ref

GATE_BACKENDS = ("ref", "pallas", "pallas_tpu")

_BACKEND = "ref"
_TILE = 256


def validate_backend(name: str) -> str:
    """Check a gate backend name; returns it (raises ValueError else)."""
    if name not in GATE_BACKENDS:
        raise ValueError(f"unknown gate_backend {name!r}; "
                         f"expected one of {GATE_BACKENDS}")
    return name


def set_backend(name: str) -> None:
    """Set the process-wide default gate backend (overridden per call).

    ``FenixConfig(gate_backend=...)`` is the usual way to pick one — it
    threads through every driver path without touching this global.
    """
    global _BACKEND
    _BACKEND = validate_backend(name)


def rate_gate(t_i: jax.Array, c_i: jax.Array, lut: jax.Array,
              *, rand16: Optional[jax.Array] = None,
              seed: Optional[jax.Array] = None,
              t_shift: int = 10, c_shift: int = 0, prob_bits: int = 16,
              backend: Optional[str] = None) -> jax.Array:
    """Selection-only probability gate: P-LUT lookup + random threshold.

      t_i, c_i  [n] int32   per-packet LUT coordinates (inter-arrival
                            time and flow count), bucketed by
                            ``>> t_shift`` / ``>> c_shift`` and clipped
                            to the LUT's edges
      lut       [T, C] i32  admission probabilities as fixed-point
                            fractions of 2^prob_bits
      rand16    [n] int32   uniform draws in [0, 2^prob_bits) — required
                            for "ref"/"pallas" (deterministic replay);
                            "pallas_tpu" can instead derive them from
                            ``seed`` with the on-core PRNG

    Returns [n] bool: ``rand16 < lut[t_i >> t_shift, c_i >> c_shift]``.
    ``backend`` overrides the process default; the Pallas backends pad n
    to the 256-lane tile internally and slice back.  Kept unfused for
    benchmarks and kernel sweeps — the Data Engine serves through
    :func:`fused_admission`.
    """
    backend = validate_backend(backend or _BACKEND)
    n = t_i.shape[0]
    if backend == "ref":
        assert rand16 is not None
        return rate_gate_ref(t_i, c_i, lut, rand16, t_shift, c_shift)
    tile = _TILE
    pad = (-n) % tile
    if pad:
        t_i = jnp.pad(t_i, (0, pad))
        c_i = jnp.pad(c_i, (0, pad))
    use_tpu_prng = backend == "pallas_tpu"
    if rand16 is None and not use_tpu_prng:
        key = jax.random.PRNGKey(int(seed) if seed is not None else 0)
        rand16 = jax.random.randint(key, (t_i.shape[0],), 0,
                                    1 << prob_bits, jnp.int32)
    elif rand16 is not None and pad:
        rand16 = jnp.pad(rand16, (0, pad))
    sel = rate_gate_pallas(t_i, c_i, lut,
                           seed if seed is not None else jnp.zeros((), jnp.int32),
                           rand16=rand16,
                           t_shift=t_shift, c_shift=c_shift,
                           prob_bits=prob_bits, tile=tile,
                           interpret=(backend == "pallas"),
                           use_tpu_prng=use_tpu_prng)
    return sel[:n].astype(bool)


def fused_admission(t_i: jax.Array, c_i: jax.Array, ts: jax.Array,
                    lut: jax.Array, bucket: jax.Array, t_last: jax.Array,
                    *, rand16: Optional[jax.Array] = None,
                    seed: Optional[jax.Array] = None,
                    cost_us: int, bucket_cap_us: int,
                    t_shift: int = 10, c_shift: int = 0,
                    prob_bits: int = 16,
                    backend: Optional[str] = None,
                    interpret: Optional[bool] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """One fused admission call per chunk: (granted [n] bool, bucket' i32).

    ``bucket``/``t_last`` are the batch-start token-bucket registers; the
    refill anchor and the burst cap are derived here exactly as the
    historical inline math did, so ``backend="ref"`` is bit-identical to
    the pre-fusion Data Engine.  ``interpret`` overrides the pallas
    interpret flag (the CPU lowering probe passes False explicitly).
    """
    backend = validate_backend(backend or _BACKEND)
    n = t_i.shape[0]
    t_ref = jnp.where(t_last == 0, ts[0], t_last).astype(jnp.int32)
    burst0 = jnp.minimum(bucket, bucket_cap_us).astype(jnp.int32)
    if backend == "ref":
        assert rand16 is not None
        return fused_admission_ref(t_i, c_i, ts, lut, rand16, burst0,
                                   t_ref, t_shift, c_shift, cost_us,
                                   bucket_cap_us)
    tile = _TILE
    pad = (-n) % tile
    if pad:
        t_i = jnp.pad(t_i, (0, pad))
        c_i = jnp.pad(c_i, (0, pad))
        # pads keep the final timestamp so the last tile's credit — the
        # bucket-level update — is the true batch-end credit
        ts = jnp.pad(ts, (0, pad), mode="edge")
    use_tpu_prng = backend == "pallas_tpu"
    if not use_tpu_prng:
        assert rand16 is not None
        if pad:
            rand16 = jnp.pad(rand16, (0, pad))
    seed = (seed if seed is not None
            else (rand16[0] if rand16 is not None
                  else jnp.zeros((), jnp.int32)))
    scal = jnp.stack([burst0, t_ref, jnp.asarray(n, jnp.int32),
                      jnp.asarray(seed, jnp.int32)])
    granted, bucket_new = fused_gate_pallas(
        t_i, c_i, ts, lut, scal, rand16=rand16,
        t_shift=t_shift, c_shift=c_shift, prob_bits=prob_bits,
        cost_us=cost_us, bucket_cap_us=bucket_cap_us, tile=tile,
        interpret=(backend == "pallas" if interpret is None else interpret),
        use_tpu_prng=use_tpu_prng)
    return granted[:n].astype(bool), bucket_new[0]


def gate_lowering_supported() -> Tuple[bool, str]:
    """Probe whether the fused kernel compiles (interpret=False) on the
    default jax backend.

    Returns (supported, detail).  TPU hosts compile for real; most CPU
    jaxlibs have no non-interpret Pallas lowering and report the failure
    reason instead — the CI lowering job turns that into an explicit
    skip marker rather than a silent interpret fallback.
    """
    try:
        n = _TILE
        granted, bucket = fused_admission(
            jnp.zeros((n,), jnp.int32), jnp.zeros((n,), jnp.int32),
            jnp.zeros((n,), jnp.int32), jnp.zeros((4, 4), jnp.int32),
            jnp.asarray(8, jnp.int32), jnp.asarray(0, jnp.int32),
            rand16=jnp.zeros((n,), jnp.int32), cost_us=1,
            bucket_cap_us=8, backend="pallas", interpret=False)
        jax.block_until_ready((granted, bucket))
        return True, f"compiled on {jax.default_backend()}"
    except Exception as e:  # noqa: BLE001 — any lowering failure is a skip
        return False, f"{type(e).__name__}: {e}"
