"""jit'd wrapper for the rate gate: backend switch, padding, rand supply.

In ``ref`` mode the caller supplies random bits (jax.random) so results are
bit-exact reproducible; in pallas modes the on-core PRNG generates them.
The *selection* distribution is identical (uniform 16-bit threshold).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.rate_gate.kernel import rate_gate_pallas
from repro.kernels.rate_gate.ref import rate_gate_ref

_BACKEND = "ref"


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("ref", "pallas", "pallas_tpu")
    _BACKEND = name


def rate_gate(t_i: jax.Array, c_i: jax.Array, lut: jax.Array,
              *, rand16: Optional[jax.Array] = None,
              seed: Optional[jax.Array] = None,
              t_shift: int = 10, c_shift: int = 0, prob_bits: int = 16,
              backend: Optional[str] = None) -> jax.Array:
    backend = backend or _BACKEND
    n = t_i.shape[0]
    if backend == "ref":
        assert rand16 is not None
        return rate_gate_ref(t_i, c_i, lut, rand16, t_shift, c_shift)
    tile = 256
    pad = (-n) % tile
    if pad:
        t_i = jnp.pad(t_i, (0, pad))
        c_i = jnp.pad(c_i, (0, pad))
    use_tpu_prng = backend == "pallas_tpu"
    if rand16 is None and not use_tpu_prng:
        key = jax.random.PRNGKey(int(seed) if seed is not None else 0)
        rand16 = jax.random.randint(key, (t_i.shape[0],), 0,
                                    1 << prob_bits, jnp.int32)
    elif rand16 is not None and pad:
        rand16 = jnp.pad(rand16, (0, pad))
    sel = rate_gate_pallas(t_i, c_i, lut,
                           seed if seed is not None else jnp.zeros((), jnp.int32),
                           rand16=rand16,
                           t_shift=t_shift, c_shift=c_shift,
                           prob_bits=prob_bits, tile=tile,
                           interpret=(backend == "pallas"),
                           use_tpu_prng=use_tpu_prng)
    return sel[:n].astype(bool)
