"""Pure-jnp oracles for the Rate-Limiter gate (§4.2, Algorithm 1).

``rate_gate_ref`` is the selection-only core of lines 6-8: bin (T_i, C_i)
with shifts, look up the probability, compare with a uniform 16-bit draw.

``fused_admission_ref`` is the numerics oracle for the *fused* admission
kernel: selection plus the prefix-sum token-bucket credit check and the
bucket-level update, in exactly the integer op order the vectorized fast
path has always used — the Pallas kernel must be bit-identical to this
(asserted in tests/test_fused_gate.py).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

I32 = jnp.int32


def lut_prob(lut: jax.Array, t_i: jax.Array, c_i: jax.Array,
             t_shift: int, c_shift: int) -> jax.Array:
    """Shared binning + gather: the switch's shift/clip/SRAM-read.

    Works on scalars (the per-packet scan in rate_limiter.step) and on
    [N] batches (the vectorized fast path) alike.
    """
    tb, cb = lut.shape
    ti = jnp.clip(t_i >> t_shift, 0, tb - 1)
    ci = jnp.clip(c_i >> c_shift, 0, cb - 1)
    return lut[ti, ci]


def rate_gate_ref(t_i: jax.Array, c_i: jax.Array, lut: jax.Array,
                  rand16: jax.Array, t_shift: int, c_shift: int
                  ) -> jax.Array:
    """t_i/c_i/rand16 [N] int32; lut [TB,CB] int32 -> selected [N] bool."""
    return rand16 < lut_prob(lut, t_i, c_i, t_shift, c_shift)


def fused_admission_ref(t_i: jax.Array, c_i: jax.Array, ts: jax.Array,
                        lut: jax.Array, rand16: jax.Array,
                        burst0: jax.Array, t_ref: jax.Array,
                        t_shift: int, c_shift: int, cost_us: int,
                        bucket_cap_us: int
                        ) -> Tuple[jax.Array, jax.Array]:
    """Fused admission oracle: (granted [N] bool, bucket_new scalar i32).

    ``burst0`` is the batch-start bucket credit already capped at
    ``bucket_cap_us``; ``t_ref`` the refill anchor (ts[0] on the first
    batch, else the previous batch's last timestamp).  Selected packets
    spend ``cost_us`` each while their cumulative spend fits the credit
    available at their arrival — the documented prefix-sum approximation
    of the shared token bucket.
    """
    selected = rate_gate_ref(t_i, c_i, lut, rand16, t_shift, c_shift)
    credit = burst0 + jnp.maximum(ts - t_ref, 0)
    spend = jnp.cumsum(jnp.where(selected, cost_us, 0).astype(I32))
    granted = selected & (spend <= credit)
    bucket_new = jnp.clip(
        credit[-1] - jnp.sum(granted.astype(I32)) * cost_us,
        0, bucket_cap_us).astype(I32)
    return granted, bucket_new
