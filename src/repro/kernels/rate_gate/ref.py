"""Pure-jnp oracle for the Rate-Limiter gate (LUT lookup + threshold).

The vectorizable core of Algorithm 1 lines 6-8: bin (T_i, C_i) with shifts,
look up the probability, compare with a uniform 16-bit draw.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rate_gate_ref(t_i: jax.Array, c_i: jax.Array, lut: jax.Array,
                  rand16: jax.Array, t_shift: int, c_shift: int
                  ) -> jax.Array:
    """t_i/c_i/rand16 [N] int32; lut [TB,CB] int32 -> selected [N] bool."""
    tb, cb = lut.shape
    ti = jnp.clip(t_i >> t_shift, 0, tb - 1)
    ci = jnp.clip(c_i >> c_shift, 0, cb - 1)
    prob = lut[ti, ci]
    return rand16 < prob
