"""Pallas TPU kernel: Rate-Limiter probability gate over a packet tile.

Data-Engine hot spot (§4.2): per-packet probability lookup + random
threshold, vectorized over packet tiles.  The LUT stays VMEM-resident (the
"SRAM" of the switch); the lookup is computed as a one-hot matmul —

    prob = (onehot(ti) @ LUT) . onehot(ci)   row-wise

which maps the TCAM/SRAM table access onto the MXU instead of a serial
gather (TPU has no efficient per-lane dynamic VMEM indexing; the one-hot
contraction IS the idiomatic port).

Randomness: on real TPU (``use_tpu_prng=True``) the on-core PRNG
(pltpu.prng_seed + prng_random_bits) draws 16-bit uniforms; the CPU
interpret path takes a precomputed rand tile instead (prng primitives have
no CPU lowering) — the selection math is identical either way and the
TPU path is exercised by the lowering test.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

I32 = jnp.int32


def _lut_lookup(t, c, lut_ref, t_shift, c_shift):
    tb, cb = lut_ref.shape
    tile = t.shape[0]
    ti = jnp.clip(t >> t_shift, 0, tb - 1)
    ci = jnp.clip(c >> c_shift, 0, cb - 1)
    rows = jax.lax.broadcasted_iota(I32, (tile, tb), 1)
    onehot_t = (rows == ti[:, None]).astype(jnp.float32)
    lut_rows = jax.lax.dot_general(
        onehot_t, lut_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    cols = jax.lax.broadcasted_iota(I32, (tile, cb), 1)
    onehot_c = (cols == ci[:, None]).astype(jnp.float32)
    return jnp.sum(lut_rows * onehot_c, axis=-1).astype(I32)


def _kernel_prng(seed_ref, t_ref, c_ref, lut_ref, o_ref, *, t_shift: int,
                 c_shift: int, prob_bits: int):
    i = pl.program_id(0)
    prob = _lut_lookup(t_ref[...], c_ref[...], lut_ref, t_shift, c_shift)
    pltpu.prng_seed(seed_ref[0] + i)
    bits = pltpu.prng_random_bits((t_ref.shape[0],))
    rand16 = jnp.bitwise_and(bits.astype(jnp.uint32),
                             jnp.uint32((1 << prob_bits) - 1)).astype(I32)
    o_ref[...] = (rand16 < prob).astype(I32)


def _kernel_randin(t_ref, c_ref, lut_ref, r_ref, o_ref, *, t_shift: int,
                   c_shift: int, prob_bits: int):
    prob = _lut_lookup(t_ref[...], c_ref[...], lut_ref, t_shift, c_shift)
    o_ref[...] = (r_ref[...] < prob).astype(I32)


@functools.partial(jax.jit, static_argnames=("t_shift", "c_shift",
                                             "prob_bits", "tile",
                                             "interpret", "use_tpu_prng"))
def rate_gate_pallas(t_i: jax.Array, c_i: jax.Array, lut: jax.Array,
                     seed: jax.Array, rand16: jax.Array = None,
                     t_shift: int = 10, c_shift: int = 0,
                     prob_bits: int = 16, tile: int = 256,
                     interpret: bool = True,
                     use_tpu_prng: bool = False) -> jax.Array:
    """t_i/c_i [N] int32 (N % tile == 0) -> selected mask [N] int32."""
    n = t_i.shape[0]
    assert n % tile == 0, (n, tile)
    grid = (n // tile,)
    tile_spec = pl.BlockSpec((tile,), lambda i: (i,))
    lut_spec = pl.BlockSpec(lut.shape, lambda i: (0, 0))
    if use_tpu_prng:
        return pl.pallas_call(
            functools.partial(_kernel_prng, t_shift=t_shift,
                              c_shift=c_shift, prob_bits=prob_bits),
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                      tile_spec, tile_spec, lut_spec],
            out_specs=tile_spec,
            out_shape=jax.ShapeDtypeStruct((n,), I32),
            interpret=interpret,
        )(seed.reshape(1).astype(I32), t_i, c_i, lut)
    assert rand16 is not None
    return pl.pallas_call(
        functools.partial(_kernel_randin, t_shift=t_shift, c_shift=c_shift,
                          prob_bits=prob_bits),
        grid=grid,
        in_specs=[tile_spec, tile_spec, lut_spec, tile_spec],
        out_specs=tile_spec,
        out_shape=jax.ShapeDtypeStruct((n,), I32),
        interpret=interpret,
    )(t_i, c_i, lut, rand16)
