"""Pallas TPU kernels: Rate-Limiter gate over packet tiles (§4.2).

Two generations of the Data-Engine hot spot live here:

* ``rate_gate_pallas`` — the original *selection-only* kernel: per-packet
  probability lookup + random threshold.  The token-bucket credit check
  stayed outside as separate XLA ops (the "LUT gather beside the scan").
* ``fused_gate_pallas`` — the fused admission kernel: LUT lookup,
  threshold draw, AND the prefix-sum token-bucket credit check in one
  ``pallas_call`` per chunk.  The bucket state rides in SMEM scalars, the
  running spend / grant totals carry across the (sequential) grid in SMEM
  scratch, and the kernel emits the grant mask plus the updated bucket
  level directly — admission is one kernel call, nothing runs beside it.

The LUT stays VMEM-resident (the "SRAM" of the switch); the lookup is
computed as a one-hot matmul —

    prob = (onehot(ti) @ LUT) . onehot(ci)   row-wise

which maps the TCAM/SRAM table access onto the MXU instead of a serial
gather (TPU has no efficient per-lane dynamic VMEM indexing; the one-hot
contraction IS the idiomatic port).

Randomness: on real TPU (``use_tpu_prng=True``) the on-core PRNG
(pltpu.prng_seed + prng_random_bits) draws 16-bit uniforms; the CPU
interpret path takes a precomputed rand tile instead (prng primitives have
no CPU lowering) — the selection math is identical either way and the
TPU path is exercised by the lowering test.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

I32 = jnp.int32


def _lut_lookup(t, c, lut_ref, t_shift, c_shift):
    tb, cb = lut_ref.shape
    tile = t.shape[0]
    ti = jnp.clip(t >> t_shift, 0, tb - 1)
    ci = jnp.clip(c >> c_shift, 0, cb - 1)
    rows = jax.lax.broadcasted_iota(I32, (tile, tb), 1)
    onehot_t = (rows == ti[:, None]).astype(jnp.float32)
    lut_rows = jax.lax.dot_general(
        onehot_t, lut_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    cols = jax.lax.broadcasted_iota(I32, (tile, cb), 1)
    onehot_c = (cols == ci[:, None]).astype(jnp.float32)
    return jnp.sum(lut_rows * onehot_c, axis=-1).astype(I32)


def _kernel_prng(seed_ref, t_ref, c_ref, lut_ref, o_ref, *, t_shift: int,
                 c_shift: int, prob_bits: int):
    i = pl.program_id(0)
    prob = _lut_lookup(t_ref[...], c_ref[...], lut_ref, t_shift, c_shift)
    pltpu.prng_seed(seed_ref[0] + i)
    bits = pltpu.prng_random_bits((t_ref.shape[0],))
    rand16 = jnp.bitwise_and(bits.astype(jnp.uint32),
                             jnp.uint32((1 << prob_bits) - 1)).astype(I32)
    o_ref[...] = (rand16 < prob).astype(I32)


def _kernel_randin(t_ref, c_ref, lut_ref, r_ref, o_ref, *, t_shift: int,
                   c_shift: int, prob_bits: int):
    prob = _lut_lookup(t_ref[...], c_ref[...], lut_ref, t_shift, c_shift)
    o_ref[...] = (r_ref[...] < prob).astype(I32)


@functools.partial(jax.jit, static_argnames=("t_shift", "c_shift",
                                             "prob_bits", "tile",
                                             "interpret", "use_tpu_prng"))
def rate_gate_pallas(t_i: jax.Array, c_i: jax.Array, lut: jax.Array,
                     seed: jax.Array, rand16: jax.Array = None,
                     t_shift: int = 10, c_shift: int = 0,
                     prob_bits: int = 16, tile: int = 256,
                     interpret: bool = True,
                     use_tpu_prng: bool = False) -> jax.Array:
    """Selection-only kernel: [N] int32 inputs -> selected mask [N] int32.

    N must be a multiple of ``tile`` (``ops.rate_gate`` pads and slices
    back).  ``use_tpu_prng=True`` draws the 16-bit uniforms on-core from
    ``seed`` (TPU only); otherwise the caller-supplied ``rand16`` tile is
    compared — same distribution, deterministic replay.  ``interpret``
    selects the CPU Pallas interpreter vs a real TPU compile.
    """
    n = t_i.shape[0]
    assert n % tile == 0, (n, tile)
    grid = (n // tile,)
    tile_spec = pl.BlockSpec((tile,), lambda i: (i,))
    lut_spec = pl.BlockSpec(lut.shape, lambda i: (0, 0))
    if use_tpu_prng:
        return pl.pallas_call(
            functools.partial(_kernel_prng, t_shift=t_shift,
                              c_shift=c_shift, prob_bits=prob_bits),
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                      tile_spec, tile_spec, lut_spec],
            out_specs=tile_spec,
            out_shape=jax.ShapeDtypeStruct((n,), I32),
            interpret=interpret,
        )(seed.reshape(1).astype(I32), t_i, c_i, lut)
    assert rand16 is not None
    return pl.pallas_call(
        functools.partial(_kernel_randin, t_shift=t_shift, c_shift=c_shift,
                          prob_bits=prob_bits),
        grid=grid,
        in_specs=[tile_spec, tile_spec, lut_spec, tile_spec],
        out_specs=tile_spec,
        out_shape=jax.ShapeDtypeStruct((n,), I32),
        interpret=interpret,
    )(t_i, c_i, lut, rand16)


# ---------------------------------------------------------------------------
# fused admission: LUT lookup + threshold + token bucket, one kernel call
# ---------------------------------------------------------------------------
#
# SMEM scalar layout (the bucket state "refs" of the fused kernel):
#   scal[0] = burst0   bucket credit at batch start, capped at bucket_cap_us
#   scal[1] = t_ref    refill anchor: ts[0] on first batch else t_last
#   scal[2] = n_valid  real packet count (tiles past it are padding)
#   scal[3] = seed     PRNG seed (TPU on-core PRNG variant only)
#
# SMEM scratch carry across the sequential grid:
#   carry[0] = cumulative *selected* spend (the prefix-sum credit check)
#   carry[1] = cumulative *granted* count  (the bucket-level update)

def _fused_body(i, selected, ts, scal_ref, o_ref, bucket_ref, carry_ref,
                *, tile: int, cost_us: int, bucket_cap_us: int):
    """Shared admission tail: credit check + bucket level, carried in SMEM."""

    @pl.when(i == 0)
    def _():
        carry_ref[0] = 0
        carry_ref[1] = 0

    idx = i * tile + jax.lax.broadcasted_iota(I32, (tile, 1), 0)[:, 0]
    valid = idx < scal_ref[2]
    selected = selected & valid
    credit = scal_ref[0] + jnp.maximum(ts - scal_ref[1], 0)
    spend = carry_ref[0] + jnp.cumsum(
        jnp.where(selected, cost_us, 0).astype(I32))
    granted = selected & (spend <= credit)
    o_ref[...] = granted.astype(I32)
    carry_ref[0] = spend[tile - 1]
    carry_ref[1] = carry_ref[1] + jnp.sum(granted.astype(I32))
    # every step overwrites; the (sequential) last tile's value is final —
    # its credit[-1] is the batch-end credit because ts pads with ts[n-1]
    bucket_ref[0] = jnp.clip(credit[tile - 1] - carry_ref[1] * cost_us,
                             0, bucket_cap_us).astype(I32)


def _kernel_fused_randin(scal_ref, t_ref, c_ref, ts_ref, r_ref, lut_ref,
                         o_ref, bucket_ref, carry_ref, *, t_shift: int,
                         c_shift: int, prob_bits: int, cost_us: int,
                         bucket_cap_us: int, tile: int):
    i = pl.program_id(0)
    prob = _lut_lookup(t_ref[...], c_ref[...], lut_ref, t_shift, c_shift)
    selected = r_ref[...] < prob
    _fused_body(i, selected, ts_ref[...], scal_ref, o_ref, bucket_ref,
                carry_ref, tile=tile, cost_us=cost_us,
                bucket_cap_us=bucket_cap_us)


def _kernel_fused_prng(scal_ref, t_ref, c_ref, ts_ref, lut_ref,
                       o_ref, bucket_ref, carry_ref, *, t_shift: int,
                       c_shift: int, prob_bits: int, cost_us: int,
                       bucket_cap_us: int, tile: int):
    i = pl.program_id(0)
    prob = _lut_lookup(t_ref[...], c_ref[...], lut_ref, t_shift, c_shift)
    pltpu.prng_seed(scal_ref[3] + i)
    bits = pltpu.prng_random_bits((tile,))
    rand16 = jnp.bitwise_and(bits.astype(jnp.uint32),
                             jnp.uint32((1 << prob_bits) - 1)).astype(I32)
    selected = rand16 < prob
    _fused_body(i, selected, ts_ref[...], scal_ref, o_ref, bucket_ref,
                carry_ref, tile=tile, cost_us=cost_us,
                bucket_cap_us=bucket_cap_us)


@functools.partial(jax.jit, static_argnames=("t_shift", "c_shift",
                                             "prob_bits", "cost_us",
                                             "bucket_cap_us", "tile",
                                             "interpret", "use_tpu_prng"))
def fused_gate_pallas(t_i: jax.Array, c_i: jax.Array, ts: jax.Array,
                      lut: jax.Array, scal: jax.Array,
                      rand16: jax.Array = None,
                      t_shift: int = 10, c_shift: int = 0,
                      prob_bits: int = 16, cost_us: int = 1,
                      bucket_cap_us: int = 64, tile: int = 256,
                      interpret: bool = True,
                      use_tpu_prng: bool = False):
    """Fused admission over a padded batch.

    t_i/c_i/ts[/rand16] [N] int32 (N % tile == 0, pads masked by
    scal[2]); lut [TB, CB] int32; scal [4] int32 per the layout above.
    Returns (granted [N] int32, bucket_new [1] int32).
    """
    n = t_i.shape[0]
    assert n % tile == 0, (n, tile)
    grid = (n // tile,)
    tile_spec = pl.BlockSpec((tile,), lambda i: (i,))
    lut_spec = pl.BlockSpec(lut.shape, lambda i: (0, 0))
    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    kw = dict(t_shift=t_shift, c_shift=c_shift, prob_bits=prob_bits,
              cost_us=cost_us, bucket_cap_us=bucket_cap_us, tile=tile)
    out_shape = (jax.ShapeDtypeStruct((n,), I32),
                 jax.ShapeDtypeStruct((1,), I32))
    scratch = [pltpu.SMEM((2,), I32)]
    if use_tpu_prng:
        return pl.pallas_call(
            functools.partial(_kernel_fused_prng, **kw),
            grid=grid,
            in_specs=[smem, tile_spec, tile_spec, tile_spec, lut_spec],
            out_specs=(tile_spec, smem),
            out_shape=out_shape,
            scratch_shapes=scratch,
            interpret=interpret,
        )(scal.astype(I32), t_i, c_i, ts, lut)
    assert rand16 is not None
    return pl.pallas_call(
        functools.partial(_kernel_fused_randin, **kw),
        grid=grid,
        in_specs=[smem, tile_spec, tile_spec, tile_spec, tile_spec,
                  lut_spec],
        out_specs=(tile_spec, smem),
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(scal.astype(I32), t_i, c_i, ts, rand16, lut)
