"""Post-training INT8 fixed-point quantization (paper §6 "Model Training
and Quantization", Vitis-AI analogue).

Power-of-two scales everywhere ("assigns different decimal point positions
to different layers"): an activation x is represented as x_q = round(x*2^sa)
int8; a weight as w_q = round(w*2^sw).  A layer's int32 accumulator then
carries scale 2^(sa_in+sw) and is requantized to the next activation grid by
a single right-shift — no multipliers, exactly what the FPGA (and the
Pallas int8 kernel) executes.

Nonlinearities: relu is a clip; tanh (RNN cell) is a 512-entry int8 LUT
indexed by the pre-activation's high bits — the standard FPGA mapping.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.fenix_models import TrafficModelConfig
from repro.models import traffic

I32 = jnp.int32
I8 = jnp.int8


def _shift_for(absmax: float) -> int:
    """Largest s with absmax * 2^s <= 127 (decimal point position)."""
    absmax = max(float(absmax), 1e-8)
    return int(np.floor(np.log2(127.0 / absmax)))


def _q(x: np.ndarray, shift: int, dtype=np.int8) -> np.ndarray:
    lim = 127 if dtype == np.int8 else 2**31 - 1
    return np.clip(np.round(np.asarray(x, np.float64) * (1 << shift)
                            if shift >= 0 else
                            np.asarray(x, np.float64) / (1 << -shift)),
                   -lim, lim).astype(dtype)


def quantize_array(x: np.ndarray, shift: int, dtype=np.int8) -> np.ndarray:
    """Fixed-point quantize: ``round(x * 2^shift)`` saturated to dtype.

    ``shift`` is the decimal-point position (2^-shift is the grid step);
    negative shifts divide instead.  The public form of the scheme every
    weight/activation in this module uses.
    """
    return _q(x, shift, dtype)


def dequantize_array(x_q: np.ndarray, shift: int) -> np.ndarray:
    """Inverse grid map: ``x_q * 2^-shift`` (float64).

    Round-trip contract (tests/test_quantize.py): for |x| <= dtype_max *
    2^-shift, ``|dequantize(quantize(x)) - x| <= 2^-(shift+1)`` — half a
    grid step; values beyond the representable range saturate.
    """
    return np.asarray(x_q, np.float64) * (2.0 ** -shift)


def _collect_activations(params: Dict, cfg: TrafficModelConfig,
                         payloads: jax.Array) -> Dict[str, float]:
    """Float forward, recording absmax at every quantization site."""
    sites: Dict[str, float] = {}

    def rec(name, x):
        sites[name] = max(sites.get(name, 0.0), float(jnp.max(jnp.abs(x))))
        return x

    ids = traffic.bucketize(payloads, cfg)
    x = rec("embed", traffic.embed_ids(params, ids))
    if cfg.kind == "cnn":
        for i in range(len(cfg.conv_filters)):
            x = rec(f"conv{i}", jax.nn.relu(traffic._conv1d(
                x, params[f"conv{i}/w"], params[f"conv{i}/b"])))
        x = rec("pool", jnp.mean(x, axis=1))
        for i in range(len(cfg.fc_dims)):
            x = rec(f"fc{i}", jax.nn.relu(
                x @ params[f"fc{i}/w"] + params[f"fc{i}/b"]))
        rec("head", x @ params["head/w"] + params["head/b"])
    else:
        def cell(h, xt):
            pre = xt @ params["cell/wx"] + h @ params["cell/wh"] \
                + params["cell/b"]
            h2 = jnp.tanh(pre)
            return h2, pre

        h0 = jnp.zeros((x.shape[0], cfg.rnn_units), x.dtype)
        h, pres = jax.lax.scan(cell, h0, x.swapaxes(0, 1))
        rec("cell_pre", pres)
        rec("cell", h)
        rec("head", h @ params["head/w"] + params["head/b"])
    return sites


def quantize_traffic(params: Dict, cfg: TrafficModelConfig,
                     calib_payloads: jax.Array) -> Dict:
    """Returns the integer model: int8 weights/tables + per-layer shifts."""
    sites = _collect_activations(params, cfg, calib_payloads)
    sa: Dict[str, int] = {k: min(_shift_for(v), 12)
                          for k, v in sites.items()}
    qp: Dict[str, np.ndarray] = {"cfg_shifts": sa}

    def qlayer(name, w, b, sa_in, sa_out):
        sw = min(_shift_for(np.max(np.abs(np.asarray(w)))), 12)
        qp[f"{name}/w"] = _q(np.asarray(w), sw)
        qp[f"{name}/b"] = _q(np.asarray(b), sa_in + sw, np.int32)
        shift = sa_in + sw - sa_out
        assert shift >= 0, (name, sa_in, sw, sa_out)
        qp[f"{name}/shift"] = shift

    se = sa["embed"]
    qp["embed_len/table"] = _q(np.asarray(params["embed_len/table"]), se)
    qp["embed_ipd/table"] = _q(np.asarray(params["embed_ipd/table"]), se)
    if cfg.kind == "cnn":
        prev = "embed"
        for i in range(len(cfg.conv_filters)):
            qlayer(f"conv{i}", params[f"conv{i}/w"], params[f"conv{i}/b"],
                   sa[prev], sa[f"conv{i}"])
            prev = f"conv{i}"
        # integer mean over T: (sum * mult) >> 15, then rescale to pool grid
        sa["pool"] = sa[prev]
        qp["pool/mult"] = np.int32(round((1 << 15) / cfg.seq_len))
        prev = "pool"
        for i in range(len(cfg.fc_dims)):
            qlayer(f"fc{i}", params[f"fc{i}/w"], params[f"fc{i}/b"],
                   sa[prev], sa[f"fc{i}"])
            prev = f"fc{i}"
        qlayer("head", params["head/w"], params["head/b"], sa[prev],
               max(sa["head"], 0))
    else:
        # RNN: both matmuls accumulate on the cell_pre grid
        sa_pre = sa["cell_pre"]
        sh = sa["cell"]
        swx = min(_shift_for(np.max(np.abs(np.asarray(
            params["cell/wx"])))), 12)
        swh = min(_shift_for(np.max(np.abs(np.asarray(
            params["cell/wh"])))), 12)
        qp["cell/wx"] = _q(np.asarray(params["cell/wx"]), swx)
        qp["cell/wh"] = _q(np.asarray(params["cell/wh"]), swh)
        qp["cell/b"] = _q(np.asarray(params["cell/b"]), sa["embed"] + swx,
                          np.int32)
        qp["cell/shift_x"] = sa["embed"] + swx - sa_pre
        qp["cell/shift_h"] = sh + swh - sa_pre
        assert qp["cell/shift_x"] >= 0 and qp["cell/shift_h"] >= 0
        # tanh LUT: index = clip(pre_q >> (sa_pre-4), -256, 255)
        idx = np.arange(-256, 256)
        lut_in = idx / (1 << 4)                      # pre at scale 2^-4
        qp["tanh_lut"] = _q(np.tanh(lut_in), sh)
        qp["cell/lut_preshift"] = sa_pre - 4
        qlayer("head", params["head/w"], params["head/b"], sh,
               max(sa["head"], 0))
    return jax.tree.map(jnp.asarray, qp)


# ---------------------------------------------------------------------------
# Integer-only inference (mirrors traffic.apply layer-for-layer)
# ---------------------------------------------------------------------------


def int8_apply(qp: Dict, cfg: TrafficModelConfig, payload: jax.Array,
               backend: str = "ref") -> jax.Array:
    """payload [B,T,2] int32 -> logits int32 [B,classes]. Integer path."""
    from repro.kernels.int8_matmul.ops import int8_conv1d, int8_matmul

    ids = traffic.bucketize(payload, cfg)
    el = jnp.take(qp["embed_len/table"], ids[..., 0], axis=0)
    ei = jnp.take(qp["embed_ipd/table"], ids[..., 1], axis=0)
    x = jnp.concatenate([el, ei], axis=-1)            # int8 [B,T,2E]
    b, t, _ = x.shape
    if cfg.kind == "cnn":
        for i in range(len(cfg.conv_filters)):
            x = int8_conv1d(x, qp[f"conv{i}/w"], qp[f"conv{i}/b"],
                            int(qp[f"conv{i}/shift"]), backend=backend)
            x = jnp.maximum(x, 0)                     # relu on the int8 grid
        xs = jnp.sum(x.astype(I32), axis=1)           # [B, C]
        x = ((xs * qp["pool/mult"]) >> 15).astype(I8)
        for i in range(len(cfg.fc_dims)):
            x = int8_matmul(x, qp[f"fc{i}/w"], qp[f"fc{i}/b"],
                            int(qp[f"fc{i}/shift"]), backend=backend)
            x = jnp.maximum(x, 0)
        return int8_matmul(x, qp["head/w"], qp["head/b"], None,
                           backend=backend)
    # rnn
    def cell(h, xt):
        accx = int8_matmul(xt, qp["cell/wx"], qp["cell/b"], None,
                           backend=backend)
        acch = int8_matmul(h, qp["cell/wh"], None, None, backend=backend)
        sx = int(qp["cell/shift_x"])
        sh_ = int(qp["cell/shift_h"])
        pre = (accx >> sx if sx > 0 else accx) \
            + (acch >> sh_ if sh_ > 0 else acch)      # on the cell_pre grid
        lidx = jnp.clip(pre >> int(qp["cell/lut_preshift"]), -256, 255)
        h2 = qp["tanh_lut"][lidx + 256]
        return h2, None

    h0 = jnp.zeros((b, cfg.rnn_units), I8)
    h, _ = jax.lax.scan(cell, h0, x.swapaxes(0, 1))
    return int8_matmul(h, qp["head/w"], qp["head/b"], None, backend=backend)
