"""Roofline analysis from the dry-run artifacts (§Roofline deliverable).

Three terms per (arch x shape), single-pod mesh, TPU v5e constants:

  compute    = HLO_FLOPs / (chips * 197e12)        [s]
  memory     = HLO_bytes / (chips * 819e9)         [s]
  collective = coll_bytes_global / (chips * 50e9)  [s]

HLO_FLOPs/bytes come from the two-point layer extrapolation (cost_*.json,
exact for homogeneous stacks — see run_all_dryruns.py); collective bytes
are parsed per-device from the post-SPMD HLO, so global = per_device*chips.
MODEL_FLOPS = 6*N*D (2*N*D + attention for inference shapes) flags
remat/dispatch waste via the useful-compute ratio.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config
from repro.models.api import model_flops

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")


def load_cells(tag: str = "baseline") -> List[Dict]:
    """Join cost_* (extrapolated) with proof_* (memory) per cell."""
    tagdir = os.path.join(RESULTS_DIR, tag)
    cells = []
    for path in sorted(glob.glob(os.path.join(tagdir, "cost_*.json"))):
        cost = json.load(open(path))
        arch, shape = cost["arch"], cost["shape"]
        cell = {"arch": arch, "shape": shape, "status": cost["status"]}
        if cost["status"] != "ok":
            cells.append(cell)
            continue
        proof_p = os.path.join(tagdir, f"proof_{arch}_{shape}_single.json")
        proof = json.load(open(proof_p)) if os.path.exists(proof_p) else {}
        cell.update(analyse(arch, shape, cost, proof))
        cells.append(cell)
    for path in sorted(glob.glob(os.path.join(tagdir, "skip_*.json"))):
        cells.append(json.load(open(path)))
    return cells


_ACT_RW_PER_LAYER = 8.0   # residual-equivalent reads+writes, fused blocks


def _layers_of(cfg) -> int:
    if cfg.family == "encdec":
        return cfg.num_encoder_layers + cfg.num_decoder_layers
    return cfg.num_layers


def analytic_memory_bytes(cfg, shape, arg_bytes_dev: float,
                          overrides: Dict, chips: int = 256) -> float:
    """Fused-TPU memory floor, per device.

    args r/w (params/opt/cache/batch; dtype effects like int8 weights or
    int8 KV arrive through arg_bytes_dev, which is extrapolated from the
    variant's own dry-run) + activation residual traffic. Raw
    bytes_accessed from XLA:CPU is kept as the *unfused upper bound* (the
    CPU backend materializes f32 converts around every bf16 dot).
    """
    d, ll = cfg.d_model, _layers_of(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        remat = str(overrides.get("remat_policy", cfg.remat_policy))
        fwd_mult = {"nothing": 3.0, "dots": 2.5, "none": 2.0}.get(remat, 3.0)
        args_rw = 2.0 * arg_bytes_dev           # read + write params/opt
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        fwd_mult = 1.0
        args_rw = arg_bytes_dev                 # read params, write cache
    else:
        tokens = shape.global_batch
        fwd_mult = 1.0
        args_rw = arg_bytes_dev                 # read params + cache
    act = _ACT_RW_PER_LAYER * fwd_mult * tokens * d * ll * 2.0 / chips
    return args_rw + act


def analyse(arch: str, shape_name: str, cost: Dict,
            proof: Optional[Dict] = None, chips: int = 256) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    flops_dev = cost["flops"]                     # per-device (SPMD module)
    bytes_raw = cost["bytes_accessed"]
    overrides = {}
    pts = cost.get("point_results") or []
    if pts:
        overrides = pts[0].get("overrides", {})
    arg_dev = cost.get("arg_bytes_per_device")
    if arg_dev is None and len(pts) == 2 and "points" in cost:
        a1 = pts[0]["memory"]["arg_bytes_per_device_analytic"]
        a2 = pts[1]["memory"]["arg_bytes_per_device_analytic"]
        x1, x2 = cost["points"]
        arg_dev = a1 + (a2 - a1) / (x2 - x1) * (cost["x_full"] - x1)
    bytes_dev = analytic_memory_bytes(cfg, shape, arg_dev or 0.0,
                                      overrides, chips)
    coll_dev = cost.get("collective_bytes", 0.0)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global = flops_dev * chips
    out = {
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "bytes_per_device_raw": bytes_raw,
        "collective_bytes_per_device": coll_dev,
        "collective_per_op": cost.get("collective_bytes_per_op", {}),
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "step_time_s": max(terms.values()),
        "roofline_fraction": t_compute / max(terms.values())
        if max(terms.values()) > 0 else 0.0,
        "mfu_vs_model_flops": (mf / chips / PEAK_FLOPS)
        / max(terms.values()) if max(terms.values()) > 0 else 0.0,
    }
    if proof and proof.get("status") == "ok":
        mem = proof.get("memory", {})
        out["hbm_args_gb"] = (mem.get("argument_bytes") or 0) / 1e9
        out["hbm_temp_gb"] = (mem.get("temp_bytes") or 0) / 1e9
        out["fits_16gb"] = (out["hbm_args_gb"] + out["hbm_temp_gb"]) <= 16.0
        out["compile_s"] = proof.get("compile_s")
    return out


def suggestion(cell: Dict) -> str:
    d = cell.get("dominant")
    if d == "collective":
        ops = cell.get("collective_per_op", {})
        top = max(ops, key=ops.get) if ops else "?"
        return (f"dominant {top}: reshard to cut it (MoE dispatch all-to-all"
                f" / weight-gather batching)")
    if d == "memory":
        return "cut bytes: int8 weights, fused attention (no score spill), " \
               "bf16 cache"
    return "compute-bound: reduce remat recompute / causal-band waste"


def table(cells: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| 6ND/HLO | MFU | fits16GB |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    for c in sorted(cells, key=lambda x: (x["arch"], x["shape"])):
        if c.get("status") == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | skipped"
                        f" | — | — | — |")
            continue
        if c.get("status") != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | ? | ? | ? | error "
                        f"| ? | ? | ? |")
            continue
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['compute_s']:.3e} "
            f"| {c['memory_s']:.3e} | {c['collective_s']:.3e} "
            f"| {c['dominant']} | {c['useful_ratio']:.2f} "
            f"| {c['mfu_vs_model_flops']*100:.1f}% "
            f"| {'Y' if c.get('fits_16gb') else 'N'} |")
    return "\n".join(rows)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    cells = load_cells(args.tag)
    print(table(cells))
    for c in cells:
        if c.get("status") == "ok":
            print(f"- {c['arch']} x {c['shape']}: {suggestion(c)}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(cells, f, indent=1, default=str)


if __name__ == "__main__":
    main()
