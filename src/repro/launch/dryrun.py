import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
mesh, with 512 placeholder host devices (the two lines above MUST stay first).

For each cell this produces:
  - proof of compile (sharding coherence) on (16,16) and (2,16,16) meshes
  - memory_analysis()  — per-device bytes (fits / doesn't fit)
  - cost_analysis()    — HLO FLOPs + bytes for the roofline terms
  - collective bytes   — parsed from the post-SPMD HLO text
written as JSON under benchmarks/results/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k --mesh single [--set attention_impl=chunked] \
      [--rule expert_cap=data] [--out results.json]
"""

import argparse
import dataclasses
import json
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, shape_applicable
from repro.launch.mesh import data_axes, make_production_mesh
from repro.models import api
from repro.models.param import (sharding_ctx, sharding_fallbacks, spec_for,
                                tree_pspecs)
from repro.train import optimizer as opt_lib

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def op_byte_histogram(hlo_text: str, top: int = 24) -> Dict[str, float]:
    """Result bytes per HLO opcode (per-device). Used to adjust the memory
    roofline term: XLA:CPU materializes bf16->f32 ``convert``s around every
    dot (no native bf16 GEMM) and dus ``copy``s that TPU's native-bf16 MXU
    and donation elide — those bytes are a backend artifact, not HBM
    traffic the TPU would see."""
    import collections
    sizes: Dict[str, float] = collections.Counter()
    for m in re.finditer(r"=\s*(\w+)\[([0-9,]*)\][^ ]*\s+([a-z][a-z0-9\-.]*)",
                         hlo_text):
        dt, dims, op = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        sizes[op] += n * _DTYPE_BYTES[dt]
    return dict(collections.Counter(sizes).most_common(top))


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Sum operand bytes of every collective op in a post-SPMD HLO module.

    Shapes in the partitioned module are per-device, so the totals here are
    per-device bytes moved over ICI; multiply by chip count for global.
    """
    # name -> result type string (first occurrence of "%name = <type>")
    def_types: Dict[str, str] = {}
    def_re = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*([^=]+?)\s+"
                        r"([a-z][a-z0-9\-]*)\(")
    lines = hlo_text.splitlines()
    for ln in lines:
        m = def_re.match(ln)
        if m:
            def_types[m.group(1)] = m.group(2)
    per_op: Dict[str, Dict[str, float]] = {}
    for ln in lines:
        m = def_re.match(ln)
        if not m:
            continue
        op = m.group(3)
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):  # -start/-done variants
                base = c
                break
        if base is None or op.endswith("-done"):
            continue
        # operand names: %name tokens inside the call parens
        call = ln[m.end():]
        operand_bytes = 0
        for nm in re.findall(r"%([\w.\-]+)", call):
            t = def_types.get(nm)
            if t:
                operand_bytes += _type_bytes(t)
        if operand_bytes == 0:  # fall back to result size
            operand_bytes = _type_bytes(m.group(2))
        d = per_op.setdefault(base, {"count": 0, "bytes": 0.0})
        d["count"] += 1
        d["bytes"] += operand_bytes
    total = sum(d["bytes"] for d in per_op.values())
    return {"per_op": per_op, "total_bytes": total}


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def apply_overrides(cfg, overrides: Dict[str, str]):
    for key, val in overrides.items():
        parts = key.split(".")
        def parse(v):
            for cast in (int, float):
                try:
                    return cast(v)
                except ValueError:
                    pass
            if v in ("true", "false", "True", "False"):
                return v.lower() == "true"
            return v
        v = parse(val)
        if len(parts) == 1:
            cfg = dataclasses.replace(cfg, **{parts[0]: v})
        elif len(parts) == 2:
            sub = getattr(cfg, parts[0])
            cfg = dataclasses.replace(
                cfg, **{parts[0]: dataclasses.replace(sub, **{parts[1]: v})})
        else:
            raise ValueError(key)
    return cfg


def _ns(mesh, tree):
    """Wrap a PartitionSpec pytree in NamedShardings for this mesh."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))

def build_lowered(cfg, shape, mesh, rules: Optional[Dict] = None):
    """Returns (lowered, meta). Must be called inside sharding_ctx."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    if shape.global_batch % dp_size != 0:
        dp = ()  # e.g. long_500k batch=1: replicate the batch dim
    params, axes = api.init_params(cfg, abstract=True)
    if cfg.quant == "int8" and shape.kind != "train":
        params, axes = api.quantize_for_serving(cfg, params, axes)
    p_specs = tree_pspecs(params, axes, mesh, rules)
    specs = api.input_specs(cfg, shape)
    n_params = sum(int(np.prod(v.shape)) for v in params.values())

    def batch_pspec(v):
        if not dp:
            return P()
        ax = (dp,) + (None,) * (len(v.shape) - 1)
        return P(*ax)

    if shape.kind == "train":
        opt_state = opt_lib.abstract_state(params)
        o_specs = {"m": p_specs, "v": p_specs, "step": P()}
        b_specs = {k: batch_pspec(v) for k, v in specs.items()}
        ocfg = opt_lib.OptConfig()

        def train_step(params, opt, batch):
            def lfn(p, b):
                return api.loss_fn(p, cfg, b)
            (loss, metrics), grads = jax.value_and_grad(
                lfn, has_aux=True)(params, batch)
            params, opt, om = opt_lib.apply_updates(grads=grads, state=opt,
                                                    params=params, cfg=ocfg)
            return params, opt, {"loss": loss}

        jitted = jax.jit(
            train_step,
            in_shardings=_ns(mesh, (p_specs, o_specs, b_specs)),
            out_shardings=_ns(mesh, (p_specs, o_specs, {"loss": P()})),
            donate_argnums=(0, 1))
        lowered = jitted.lower(params, opt_state, specs)
        arg_bytes = _tree_bytes(params) + _tree_bytes(opt_state) \
            + _tree_bytes(specs)
        arg_dev = (_tree_bytes_per_device(params, p_specs, mesh)
                   + _tree_bytes_per_device(opt_state, o_specs, mesh)
                   + _tree_bytes_per_device(specs, b_specs, mesh))
    elif shape.kind == "prefill":
        cache_ax = api.cache_pspec_axes(cfg, shape.global_batch,
                                        shape.seq_len)
        cache_specs_d = api.cache_specs(cfg, shape.global_batch,
                                        shape.seq_len)
        c_specs = {k: spec_for(cache_specs_d[k][0], ax, mesh=mesh,
                               rules=rules)
                   for k, ax in cache_ax.items()}
        b_specs = {k: batch_pspec(v) for k, v in specs.items()}

        def prefill_step(params, batch):
            cache, logits = api.prefill(params, cfg, batch)
            return cache, logits

        logit_spec = P(dp, None) if dp else P(None, None)
        jitted = jax.jit(prefill_step,
                         in_shardings=_ns(mesh, (p_specs, b_specs)),
                         out_shardings=_ns(mesh, (c_specs, logit_spec)))
        lowered = jitted.lower(params, specs)
        arg_bytes = _tree_bytes(params) + _tree_bytes(specs)
        arg_dev = (_tree_bytes_per_device(params, p_specs, mesh)
                   + _tree_bytes_per_device(specs, b_specs, mesh))
    else:  # decode
        cache_ax = api.cache_pspec_axes(cfg, shape.global_batch,
                                        shape.seq_len)
        cache = specs["cache"]
        c_specs = {k: spec_for(cache[k].shape, cache_ax[k], mesh=mesh,
                               rules=rules) for k in cache}
        tok_spec = P(dp) if dp else P(None)

        def serve_step(params, cache, tokens):
            return api.decode_step(params, cfg, cache, tokens)

        logit_spec = P(dp, None) if dp else P(None, None)
        jitted = jax.jit(serve_step,
                         in_shardings=_ns(mesh, (p_specs, c_specs, tok_spec)),
                         out_shardings=_ns(mesh, (c_specs, logit_spec)),
                         donate_argnums=(1,))
        lowered = jitted.lower(params, cache, specs["tokens"])
        arg_bytes = _tree_bytes(params) + _tree_bytes(cache)
        arg_dev = (_tree_bytes_per_device(params, p_specs, mesh)
                   + _tree_bytes_per_device(cache, c_specs, mesh))
    return lowered, {"n_params": n_params, "arg_bytes_global": arg_bytes,
                     "arg_bytes_per_device_sharded": arg_dev}


def _tree_bytes(tree) -> int:
    return sum(int(np.prod(v.shape)) * jnp.dtype(v.dtype).itemsize
               for v in jax.tree.leaves(tree))


def _tree_bytes_per_device(tree, specs, mesh) -> float:
    """Shard-aware per-device bytes: global / (product of spec mesh axes).
    Replicated leaves count fully on every device."""
    total = 0.0
    leaves_t = jax.tree.leaves(tree)
    leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for v, s in zip(leaves_t, leaves_s):
        n = 1
        if isinstance(s, P):
            for part in s:
                if part is None:
                    continue
                for ax in ((part,) if isinstance(part, str) else part):
                    n *= mesh.shape[ax]
        total += int(np.prod(v.shape)) * jnp.dtype(v.dtype).itemsize / n
    return total


# ---------------------------------------------------------------------------
# Main cell runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             overrides: Dict[str, str], rule_overrides: Dict[str, Any],
             save_hlo: Optional[str] = None) -> Dict[str, Any]:
    cfg = get_config(arch)
    cfg = apply_overrides(cfg, overrides)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "chips": n_chips,
        "overrides": overrides, "rules": {k: str(v) for k, v in
                                          rule_overrides.items()},
    }
    with sharding_ctx(mesh, rule_overrides or None):
        lowered, meta = build_lowered(cfg, shape, mesh,
                                      rules=None)
        result.update(meta)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        result["lower_s"] = round(t1 - t0, 2)
        result["compile_s"] = round(t2 - t1, 2)
        result["sharding_fallbacks"] = [
            {"shape": list(s), "axis": a, "mesh_axes": str(m), "dim": d,
             "size": sz} for s, a, m, d, sz in sharding_fallbacks()]
    # --- memory analysis ---
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes":
                getattr(ma, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem = {"error": str(e)}
    # analytic per-device arg bytes (params+opt+batch sharded over chips)
    mem["arg_bytes_global_analytic"] = result.pop("arg_bytes_global")
    mem["arg_bytes_per_device_analytic"] = \
        result.pop("arg_bytes_per_device_sharded", None) or \
        mem["arg_bytes_global_analytic"] / n_chips
    result["memory"] = mem
    # --- cost analysis ---
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        result["cost"] = {
            "flops": float(ca.get("flops", -1.0)),
            "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
            "transcendentals": float(ca.get("transcendentals", -1.0)),
        }
    except Exception as e:
        result["cost"] = {"error": str(e)}
    # --- collectives ---
    try:
        hlo = compiled.as_text()
        result["collectives"] = collective_stats(hlo)
        result["op_bytes"] = op_byte_histogram(hlo)
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
        result["hlo_lines"] = hlo.count("\n")
    except Exception as e:
        result["collectives"] = {"error": str(e)}
    result["status"] = "ok"
    result["total_s"] = round(time.time() - t0, 2)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (e.g. attention_impl=chunked)")
    ap.add_argument("--rule", action="append", default=[],
                    help="sharding rule override logical=mesh1[,mesh2]|none")
    ap.add_argument("--out", default=None)
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()
    overrides = dict(s.split("=", 1) for s in args.set)
    rules: Dict[str, Any] = {}
    for r in args.rule:
        k, v = r.split("=", 1)
        if v == "none":
            rules[k] = None
        else:
            ax = tuple(v.split(","))
            rules[k] = ax if len(ax) > 1 else ax[0]
    res = run_cell(args.arch, args.shape, args.mesh, overrides, rules,
                   save_hlo=args.save_hlo)
    js = json.dumps(res, indent=2, default=str)
    print(js)
    if args.out:
        with open(args.out, "w") as f:
            f.write(js)


if __name__ == "__main__":
    main()
