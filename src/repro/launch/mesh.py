"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before any jax initialization, smoke tests see the 1 real device.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh for elastic-scaling experiments."""
    return jax.make_mesh(shape, axes)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The axes a batch dim is sharded over (pod+data when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def smoke_mesh() -> Optional[Mesh]:
    """Mesh for local runs: None on 1 device (skips the SPMD pipeline —
    XLA:CPU compiles sharding-constrained scans pathologically slowly),
    a (n,1) data mesh otherwise."""
    n = len(jax.devices())
    if n == 1:
        return None
    return jax.make_mesh((n, 1), ("data", "model"))
