"""Production training launcher: ``python -m repro.launch.train --arch X``.

On this CPU container it runs reduced configs end-to-end (synthetic token
stream, AdamW, checkpoint/restart); on a real fleet the same step function
lowers onto the production mesh (launch/dryrun.py proves every cell
compiles).  Flags mirror the dry-run so a config validated there trains
here unchanged.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.dryrun import apply_overrides
from repro.launch.mesh import smoke_mesh
from repro.models import api
from repro.models.param import sharding_ctx
from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib


def token_batches(vocab: int, batch: int, seq: int, seed: int = 0):
    """Synthetic LM data: Zipf-ish ngram stream (data pipeline stand-in)."""
    rng = np.random.default_rng(seed)
    probs = 1.0 / np.arange(1, vocab + 1) ** 1.1
    probs /= probs.sum()
    while True:
        toks = rng.choice(vocab, size=(batch, seq + 1), p=probs)
        yield {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
               "labels": jnp.asarray(toks[:, 1:], jnp.int32)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--set", action="append", default=[])
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    cfg = apply_overrides(cfg, dict(s.split("=", 1) for s in args.set))
    mesh = smoke_mesh()
    params, axes = api.init_params(cfg, seed=0)
    opt_state = opt_lib.init_state(params)
    ocfg = opt_lib.OptConfig(lr=args.lr, warmup_steps=args.steps // 10,
                             total_steps=args.steps)
    step0 = 0
    if args.ckpt_dir:
        restored = ckpt_lib.restore_latest(args.ckpt_dir)
        if restored:
            state, meta = restored
            params, opt_state = state["params"], state["opt"]
            step0 = meta["step"]
            print(f"resumed from step {step0}")

    def loss(p, b):
        return api.loss_fn(p, cfg, b)

    train_step = jax.jit(opt_lib.make_train_step(loss, ocfg),
                         donate_argnums=(0, 1))
    data = token_batches(cfg.vocab_size, args.batch, args.seq)
    with sharding_ctx(mesh):
        t0 = time.time()
        for step in range(step0 + 1, args.steps + 1):
            batch = next(data)
            if cfg.family == "encdec":
                batch["src_embeds"] = jnp.zeros(
                    (args.batch, args.seq, cfg.d_model), jnp.float32)
            if cfg.family == "vlm":
                batch["image_embeds"] = jnp.zeros(
                    (args.batch, cfg.num_image_tokens, cfg.d_model),
                    jnp.float32)
            params, opt_state, metrics = train_step(params, opt_state,
                                                    batch)
            if step % 10 == 0 or step == args.steps:
                print(f"step {step}: loss={float(metrics['loss']):.4f} "
                      f"({(time.time()-t0)/max(step-step0,1):.2f}s/step)",
                      flush=True)
            if args.ckpt_dir and step % args.ckpt_every == 0:
                ckpt_lib.save(args.ckpt_dir, step,
                              {"params": params, "opt": opt_state})
    print("done")


if __name__ == "__main__":
    main()
