"""Driver: run the full dry-run matrix and persist JSON incrementally.

Per (arch x shape) cell:
  proof runs  — scanned lowering compiled on BOTH meshes (16,16) and
                (2,16,16): the runnability deliverable + memory_analysis.
  cost runs   — two unrolled reduced-layer compiles (no while ops) on the
                single-pod mesh; HLO flops / bytes / collective bytes are
                affine in layer count, so the full-depth values are the
                two-point extrapolation (exact for homogeneous stacks).

Each dryrun executes in a subprocess so jax device-count state is isolated.

Usage:
  PYTHONPATH=src python -m repro.launch.run_all_dryruns [--only arch[,arch]]
      [--shapes s1,s2] [--skip-existing] [--tag baseline]
      [--set k=v ...] [--rule k=v ...]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Tuple

from repro.configs import SHAPES, get_config, shape_applicable

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")


def cost_points(arch: str) -> Tuple[List[Dict[str, str]], List[float], float]:
    """Returns ([overrides_point1, overrides_point2], [x1, x2], x_full)."""
    cfg = get_config(arch)
    if cfg.family == "transformer":
        nf = cfg.moe.first_dense_layers if cfg.moe.num_experts else 0
        return ([{"num_layers": str(nf + 2)}, {"num_layers": str(nf + 4)}],
                [2.0, 4.0], float(cfg.num_layers - nf))
    if cfg.family == "ssm":
        return ([{"num_layers": "2"}, {"num_layers": "4"}],
                [2.0, 4.0], float(cfg.num_layers))
    if cfg.family == "hybrid":
        pat = len(cfg.hybrid.pattern)
        tail = cfg.num_layers % pat
        return ([{"num_layers": str(pat + tail)},
                 {"num_layers": str(2 * pat + tail)}],
                [1.0, 2.0], float(cfg.num_layers // pat))
    if cfg.family == "encdec":
        return ([{"num_encoder_layers": "2", "num_decoder_layers": "2"},
                 {"num_encoder_layers": "4", "num_decoder_layers": "4"}],
                [2.0, 4.0], float(cfg.num_encoder_layers))
    if cfg.family == "vlm":
        per = cfg.cross_attn_every
        return ([{"num_layers": str(per)}, {"num_layers": str(2 * per)}],
                [1.0, 2.0], float(cfg.num_layers // per))
    raise ValueError(cfg.family)


def run_dryrun(arch: str, shape: str, mesh: str, sets: Dict[str, str],
               rules: List[str], out: str, timeout: int = 3600) -> Dict:
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--mesh", mesh, "--out", out]
    for k, v in sets.items():
        cmd += ["--set", f"{k}={v}"]
    for r in rules:
        cmd += ["--rule", r]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "../..")
    t0 = time.time()
    try:
        p = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=timeout, env=env)
    except subprocess.TimeoutExpired:
        return {"status": "timeout", "arch": arch, "shape": shape,
                "mesh": mesh}
    if p.returncode != 0:
        return {"status": "error", "arch": arch, "shape": shape,
                "mesh": mesh, "stderr": p.stderr[-4000:],
                "wall_s": round(time.time() - t0, 1)}
    with open(out) as f:
        return json.load(f)


def extrapolate(p1: Dict, p2: Dict, x1: float, x2: float,
                x_full: float) -> Dict:
    def ex(a, b):
        return a + (b - a) / (x2 - x1) * (x_full - x1)

    out = {"points": [x1, x2], "x_full": x_full}
    c1, c2 = p1.get("cost", {}), p2.get("cost", {})
    for k in ("flops", "bytes_accessed", "transcendentals"):
        if k in c1 and k in c2:
            out[k] = ex(c1[k], c2[k])
    ob1, ob2 = p1.get("op_bytes", {}), p2.get("op_bytes", {})
    if ob1 and ob2:
        # CPU-backend artifact bytes (absent on native-bf16 TPU):
        # convert ~ 1.5x result (bf16 read + f32 write), copy ~ 2x result
        def artifact(ob):
            return 1.5 * ob.get("convert", 0.0) + 2.0 * ob.get("copy", 0.0)
        art = ex(artifact(ob1), artifact(ob2))
        out["artifact_bytes"] = art
        if "bytes_accessed" in out:
            out["adj_bytes_accessed"] = max(out["bytes_accessed"] - art,
                                            0.0)
        out["op_bytes_points"] = [ob1, ob2]
    col1 = p1.get("collectives", {})
    col2 = p2.get("collectives", {})
    if "total_bytes" in col1 and "total_bytes" in col2:
        out["collective_bytes"] = ex(col1["total_bytes"],
                                     col2["total_bytes"])
        per = {}
        ops = set(col1.get("per_op", {})) | set(col2.get("per_op", {}))
        for op in ops:
            b1 = col1.get("per_op", {}).get(op, {}).get("bytes", 0.0)
            b2 = col2.get("per_op", {}).get(op, {}).get("bytes", 0.0)
            per[op] = ex(b1, b2)
        out["collective_bytes_per_op"] = per
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--shapes", default=None)
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-proof", action="store_true")
    ap.add_argument("--no-cost", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--rule", action="append", default=[])
    args = ap.parse_args()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    from repro.configs import list_archs
    archs = args.only.split(",") if args.only else list(list_archs())
    shapes = args.shapes.split(",") if args.shapes else list(SHAPES)
    extra_sets = dict(s.split("=", 1) for s in args.set)

    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            ok, reason = shape_applicable(cfg, SHAPES[shape])
            tagdir = os.path.join(RESULTS_DIR, args.tag)
            os.makedirs(tagdir, exist_ok=True)
            if not ok:
                path = os.path.join(tagdir, f"skip_{arch}_{shape}.json")
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape,
                               "status": "skipped", "reason": reason}, f)
                print(f"[skip ] {arch} x {shape}: {reason}", flush=True)
                continue
            # ---- proof runs (scanned) on both meshes
            if not args.no_proof:
                for mesh in args.meshes.split(","):
                    out = os.path.join(tagdir,
                                       f"proof_{arch}_{shape}_{mesh}.json")
                    if args.skip_existing and os.path.exists(out):
                        continue
                    t0 = time.time()
                    res = run_dryrun(arch, shape, mesh, dict(extra_sets),
                                     args.rule, out)
                    with open(out, "w") as f:
                        json.dump(res, f, indent=1, default=str)
                    print(f"[proof] {arch} x {shape} x {mesh}: "
                          f"{res.get('status')} ({time.time()-t0:.0f}s)",
                          flush=True)
            # ---- cost runs (unrolled two-point) single-pod
            if not args.no_cost:
                out = os.path.join(tagdir, f"cost_{arch}_{shape}.json")
                if args.skip_existing and os.path.exists(out):
                    continue
                points, xs, x_full = cost_points(arch)
                results = []
                failed = False
                for i, ov in enumerate(points):
                    sets = {"scan_layers": "false", **ov, **extra_sets}
                    pth = os.path.join(tagdir,
                                       f".pt{i}_{arch}_{shape}.json")
                    t0 = time.time()
                    res = run_dryrun(arch, shape, "single", sets,
                                     args.rule, pth)
                    results.append(res)
                    print(f"[cost{i}] {arch} x {shape}: "
                          f"{res.get('status')} ({time.time()-t0:.0f}s)",
                          flush=True)
                    if res.get("status") != "ok":
                        failed = True
                        break
                if not failed:
                    final = extrapolate(results[0], results[1], xs[0], xs[1],
                                        x_full)
                    final.update({"arch": arch, "shape": shape,
                                  "status": "ok",
                                  "point_results": results})
                else:
                    final = {"arch": arch, "shape": shape, "status": "error",
                             "point_results": results}
                with open(out, "w") as f:
                    json.dump(final, f, indent=1, default=str)


if __name__ == "__main__":
    main()
