"""Serving launcher: ``python -m repro.launch.serve --arch X`` — batched
greedy decoding on a reduced config with optional INT8 weights and the
FENIX admission gate (core/gate.py)."""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.dryrun import apply_overrides
from repro.models import api
from repro.serve.engine import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--quant", default="none", choices=["none", "int8"])
    ap.add_argument("--gate-rate", type=float, default=None,
                    help="requests/s; enables the FENIX admission gate")
    ap.add_argument("--set", action="append", default=[])
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    cfg = apply_overrides(cfg, dict(s.split("=", 1) for s in args.set))
    params, _ = api.init_params(cfg, seed=0)
    eng = ServingEngine(cfg, params, ServeConfig(
        max_new_tokens=args.new_tokens, quant=args.quant,
        gate_backend_rate=args.gate_rate))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(
            rng.normal(0, 1, (args.batch, args.prompt_len, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(0, 1, (args.batch, cfg.num_image_tokens,
                              cfg.d_model)), jnp.float32)
    t0 = time.time()
    out = eng.generate(batch)
    print(f"arch={args.arch} quant={args.quant} "
          f"decode {out['decode_tok_per_s']:.1f} tok/s "
          f"(wall {time.time()-t0:.1f}s)")
    print("sample tokens:", np.asarray(out["tokens"])[0][:16])


if __name__ == "__main__":
    main()
