"""FENIX end-to-end system: switch (Data Engine) + FPGA (Model Engine).

Co-simulation of the asynchronous hybrid (§3, Figure 2):

  packets --> Data Engine (flow tracking, probabilistic token bucket,
              ring buffers) --> mirror packets --> Vector I/O FIFO -->
              INT8 DNN inference --> (flow id, class) --> flow table cls

The Model Engine serves at most ``service_rate`` inferences per simulated
second (the paper's F in V=min(F, B/W)); results return to the switch with
``loop_latency_us`` (PCB interconnect, Fig. 11: 1-3us).  Flows with a
verdict are classified per-packet at line rate from the flow table; packets
of unclassified flows fall back to the switch decision tree.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.fenix_models import TrafficModelConfig
from repro.core.data_engine import engine as de
from repro.core.data_engine import rate_limiter as rl
from repro.core.data_engine.state import EngineConfig, init_state
from repro.core.model_engine import vector_io as vio
from repro.core.model_engine.inference import EngineModel
from repro.core.data_engine import flow_tracker as ft


@dataclasses.dataclass
class FenixConfig:
    engine: EngineConfig = dataclasses.field(default_factory=EngineConfig)
    io: vio.IOConfig = dataclasses.field(default_factory=vio.IOConfig)
    batch_size: int = 512            # packets per data-engine step
    loop_latency_us: int = 3         # switch->FPGA->switch (Fig. 11)
    fast_mode: bool = True           # vectorized admission (simulator)
    control_plane_every: int = 8     # LUT refresh cadence (batches)


class FenixSystem:
    """Stateful co-simulation wrapper.

    ``oracle_windows``: optional (flow_feats_list) used in fast mode — the
    vectorized data plane collapses same-flow packets within a batch, so the
    simulator reconstructs each granted packet's ring window from ground
    truth ((flow_idx, flow_pos) -> F1..F9), which is exactly the window the
    sequential switch pipeline would hold.  Scan mode builds windows from
    the simulated ring itself.
    """

    def __init__(self, cfg: FenixConfig, model: EngineModel,
                 tree: Optional[Dict] = None, tree_depth: int = 4,
                 oracle_windows: Optional[List[np.ndarray]] = None):
        self.cfg = cfg
        self.model = model
        self.tree = tree
        self.tree_depth = tree_depth
        self.oracle = oracle_windows
        self.state = init_state(cfg.engine)
        self.queues = vio.init_queues(cfg.io)
        self.stats = {"packets": 0, "granted": 0, "inferences": 0,
                      "classified_pkts": 0, "tree_pkts": 0, "dropped_q": 0}
        # in-flight inference results: (deliver_ts, slot, hash, cls)
        self._inflight: List[Tuple[int, int, int, int]] = []

    # -- one simulation step ------------------------------------------------
    def step(self, packets: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Process one packet batch; returns per-packet verdicts + masks."""
        cfg = self.cfg
        n = len(packets["ts_us"])
        batch = {k: jnp.asarray(v) for k, v in packets.items()
                 if k in ("src_ip", "dst_ip", "src_port", "dst_port",
                          "proto", "ts_us", "pkt_len")}
        now = int(packets["ts_us"][-1])
        # deliver finished inferences whose latency elapsed
        self._deliver(now)
        if cfg.fast_mode:
            self.state, out = de.process_batch_fast(self.state, batch,
                                                    cfg.engine)
        else:
            self.state, out = de.process_batch(self.state, batch, cfg.engine,
                                               tree=self.tree,
                                               tree_depth=self.tree_depth)
        granted = np.asarray(out["granted"])
        slots = np.asarray(out["slot"])[granted]
        hashes = np.asarray(out["hash"])[granted]
        feats = np.asarray(out["payload"])[granted]
        if cfg.fast_mode and self.oracle is not None and \
                "flow_idx" in packets:
            from repro.data.synthetic_traffic import ring_window
            fi = packets["flow_idx"][granted]
            fp = packets["flow_pos"][granted]
            win = feats.shape[1]
            feats = np.stack([
                ring_window(self.oracle[int(a)], int(b), win)
                for a, b in zip(fi, fp)]) if len(fi) else feats
        self.queues = vio.enqueue_batch(self.queues, cfg.io, slots, hashes,
                                        feats)
        # model engine serves a batch bounded by its service rate
        span_us = max(int(packets["ts_us"][-1]) - int(packets["ts_us"][0]),
                      1)
        budget = max(1, int(cfg.engine.token_rate_per_us * span_us))
        self.queues, s2, h2, f2 = vio.dequeue_batch(self.queues, cfg.io,
                                                    budget)
        if len(s2):
            cls = np.asarray(self.model.infer(jnp.asarray(f2)))
            for i in range(len(s2)):
                self._inflight.append((now + cfg.loop_latency_us,
                                       int(s2[i]), int(h2[i]), int(cls[i])))
            self.stats["inferences"] += len(s2)
        # verdicts: flow-table class (post-delivery) else switch tree
        verdict = np.asarray(out["verdict"])
        if self.tree is not None and cfg.fast_mode:
            from repro.core.data_engine.decision_tree import predict
            feats_now = np.stack([packets["pkt_len"],
                                  np.zeros(n, np.int32)], axis=-1)
            pre = np.asarray(predict(self.tree, jnp.asarray(feats_now),
                                     self.tree_depth))
            verdict = np.where(verdict >= 0, verdict, pre)
            self.stats["tree_pkts"] += int(np.sum(np.asarray(
                out["verdict"]) < 0))
        self.stats["packets"] += n
        self.stats["granted"] += int(granted.sum())
        self.stats["classified_pkts"] += int(np.sum(verdict >= 0))
        self.stats["dropped_q"] = int(self.queues["dropped"])
        return {"verdict": verdict, "granted": granted,
                "slot": np.asarray(out["slot"])}

    def _deliver(self, now: int) -> None:
        remain = []
        for (t, slot, h, cls) in self._inflight:
            if t <= now:
                self.state = ft.apply_inference_result(
                    self.state, jnp.asarray(slot),
                    jnp.asarray(cls), jnp.asarray(h, jnp.uint32))
            else:
                remain.append((t, slot, h, cls))
        self._inflight = remain

    def control_plane(self) -> None:
        """T_w rollover: LUT refresh from observed (N, Q) + window reset."""
        self.state = rl.control_plane_update(self.state, self.cfg.engine)
        self.state = ft.window_reset(self.state, self.cfg.engine,
                                     self.state["t_last"])

    # -- full-trace driver --------------------------------------------------
    def run_trace(self, stream: Dict[str, np.ndarray],
                  labels_by_flow: Optional[np.ndarray] = None
                  ) -> Dict[str, np.ndarray]:
        """Feed a packet stream; returns per-packet verdicts."""
        cfg = self.cfg
        n = len(stream["ts_us"])
        verdicts = np.full(n, -1, np.int32)
        for i, start in enumerate(range(0, n, cfg.batch_size)):
            sl = slice(start, min(start + cfg.batch_size, n))
            batch = {k: v[sl] for k, v in stream.items()}
            out = self.step(batch)
            verdicts[sl] = out["verdict"]
            if (i + 1) % cfg.control_plane_every == 0:
                self.control_plane()
        return {"verdict": verdicts}
