"""FENIX end-to-end system: switch (Data Engine) + FPGA (Model Engine).

Co-simulation of the asynchronous hybrid (§3, Figure 2):

  packets --> Data Engine (flow tracking, probabilistic token bucket,
              ring buffers) --> mirror packets --> Vector I/O FIFO -->
              INT8 DNN inference --> (flow id, class) --> flow table cls

The Model Engine serves at most ``service_rate`` inferences per simulated
second (the paper's F in V=min(F, B/W)); results return to the switch with
``loop_latency_us`` (PCB interconnect, Fig. 11: 1-3us).  Flows with a
verdict are classified per-packet at line rate from the flow table; packets
of unclassified flows fall back to the switch decision tree.

Two trace drivers share the same semantics:

* **Device path** (default, fast mode): ``run_trace`` pre-chunks the whole
  stream into ``[n_chunks, batch_size]`` device arrays and runs a jitted
  ``lax.scan`` per control-plane window — Vector I/O enqueue/dequeue, the
  Model-Engine service budget, and the loop-latency delay line are all
  array state inside the scan, so the only host synchronization is the
  control-plane LUT rebuild at each T_w window boundary.
* **Host path** (``device_path=False`` or scan mode): the original
  batch-at-a-time ``step`` loop with Python-list in-flight results; kept as
  the reference the device path is tested against.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.fenix_models import TrafficModelConfig
from repro.core.data_engine import engine as de
from repro.core.data_engine import rate_limiter as rl
from repro.core.data_engine.state import EngineConfig, init_state
from repro.core.model_engine import delay_line as dl
from repro.core.model_engine import vector_io as vio
from repro.core.model_engine.inference import EngineModel
from repro.core.data_engine import flow_tracker as ft

I32 = jnp.int32

# packet-stream fields consumed by the data plane
PKT_KEYS = ("src_ip", "dst_ip", "src_port", "dst_port", "proto",
            "ts_us", "pkt_len")


@dataclasses.dataclass
class FenixConfig:
    engine: EngineConfig = dataclasses.field(default_factory=EngineConfig)
    io: vio.IOConfig = dataclasses.field(default_factory=vio.IOConfig)
    batch_size: int = 512            # packets per data-engine step
    loop_latency_us: int = 3         # switch->FPGA->switch (Fig. 11)
    fast_mode: bool = True           # vectorized admission (simulator)
    control_plane_every: int = 8     # LUT refresh cadence (batches)
    device_path: bool = True         # run_trace as jitted lax.scan


class FenixSystem:
    """Stateful co-simulation wrapper.

    ``oracle_windows``: optional (flow_feats_list) used in fast mode — the
    vectorized data plane collapses same-flow packets within a batch, so the
    simulator reconstructs each granted packet's ring window from ground
    truth ((flow_idx, flow_pos) -> F1..F9), which is exactly the window the
    sequential switch pipeline would hold.  Scan mode builds windows from
    the simulated ring itself.
    """

    def __init__(self, cfg: FenixConfig, model: EngineModel,
                 tree: Optional[Dict] = None, tree_depth: int = 4,
                 oracle_windows: Optional[List[np.ndarray]] = None):
        self.cfg = cfg
        self.model = model
        self.tree = tree
        self.tree_depth = tree_depth
        self.oracle = oracle_windows
        self.state = init_state(cfg.engine)
        self.queues = vio.init_queues(cfg.io)
        self.stats = {"packets": 0, "granted": 0, "inferences": 0,
                      "classified_pkts": 0, "tree_pkts": 0, "dropped_q": 0,
                      # results dropped by the fixed-capacity device delay
                      # line (always 0 on the host path, whose in-flight
                      # list is unbounded; nonzero here flags that the
                      # device run diverged and io.queue_len needs raising)
                      "dropped_inflight": 0}
        # in-flight inference results, host view: (deliver_ts, slot, h, cls)
        self._inflight: List[Tuple[int, int, int, int]] = []
        # ... and the equivalent device-resident delay line
        self._dl = dl.init(cfg.io.queue_len)
        self._dl_dirty = False
        self._scan_jit = None
        self._step_jit = None

    # -- one simulation step (host reference path) --------------------------
    def step(self, packets: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Process one packet batch; returns per-packet verdicts + masks."""
        cfg = self.cfg
        self._sync_inflight_to_host()
        n = len(packets["ts_us"])
        batch = {k: jnp.asarray(v) for k, v in packets.items()
                 if k in PKT_KEYS}
        now = int(packets["ts_us"][-1])
        # deliver finished inferences whose latency elapsed
        self._deliver(now)
        if cfg.fast_mode:
            self.state, out = de.process_batch_fast(self.state, batch,
                                                    cfg.engine)
        else:
            self.state, out = de.process_batch(self.state, batch, cfg.engine,
                                               tree=self.tree,
                                               tree_depth=self.tree_depth)
        granted = np.asarray(out["granted"])
        slots = np.asarray(out["slot"])[granted]
        hashes = np.asarray(out["hash"])[granted]
        feats = np.asarray(out["payload"])[granted]
        if cfg.fast_mode and self.oracle is not None and \
                "flow_idx" in packets:
            from repro.data.synthetic_traffic import ring_window
            fi = packets["flow_idx"][granted]
            fp = packets["flow_pos"][granted]
            win = feats.shape[1]
            feats = np.stack([
                ring_window(self.oracle[int(a)], int(b), win)
                for a, b in zip(fi, fp)]) if len(fi) else feats
        self.queues = vio.enqueue_batch(self.queues, cfg.io, slots, hashes,
                                        feats)
        # model engine serves a batch bounded by its service rate V
        # (shared float32 formula so host and device paths agree exactly)
        span_us = max(int(packets["ts_us"][-1]) - int(packets["ts_us"][0]),
                      1)
        budget = int(vio.service_budget(span_us,
                                        cfg.engine.token_rate_per_us,
                                        cfg.io.queue_len))
        self.queues, s2, h2, f2 = vio.dequeue_batch(self.queues, cfg.io,
                                                    budget)
        if len(s2):
            cls = np.asarray(self.model.infer(jnp.asarray(f2)))
            for i in range(len(s2)):
                self._inflight.append((now + cfg.loop_latency_us,
                                       int(s2[i]), int(h2[i]), int(cls[i])))
            self.stats["inferences"] += len(s2)
        # verdicts: flow-table class (post-delivery) else switch tree
        verdict = np.asarray(out["verdict"])
        if self.tree is not None and cfg.fast_mode:
            from repro.core.data_engine.decision_tree import predict
            feats_now = np.stack([packets["pkt_len"],
                                  np.zeros(n, np.int32)], axis=-1)
            pre = np.asarray(predict(self.tree, jnp.asarray(feats_now),
                                     self.tree_depth))
            verdict = np.where(verdict >= 0, verdict, pre)
            self.stats["tree_pkts"] += int(np.sum(np.asarray(
                out["verdict"]) < 0))
        self.stats["packets"] += n
        self.stats["granted"] += int(granted.sum())
        self.stats["classified_pkts"] += int(np.sum(verdict >= 0))
        self.stats["dropped_q"] = int(self.queues["dropped"])
        return {"verdict": verdict, "granted": granted,
                "slot": np.asarray(out["slot"])}

    def _deliver(self, now: int) -> None:
        remain = []
        for (t, slot, h, cls) in self._inflight:
            if t <= now:
                self.state = ft.apply_inference_result(
                    self.state, jnp.asarray(slot),
                    jnp.asarray(cls), jnp.asarray(h, jnp.uint32))
            else:
                remain.append((t, slot, h, cls))
        self._inflight = remain

    def control_plane(self) -> None:
        """T_w rollover: LUT refresh from observed (N, Q) + window reset."""
        self.state = rl.control_plane_update(self.state, self.cfg.engine)
        self.state = ft.window_reset(self.state, self.cfg.engine,
                                     self.state["t_last"])

    # -- in-flight state interop (host list <-> device delay line) ----------
    def _sync_inflight_to_host(self) -> None:
        if self._dl_dirty:
            self._inflight = dl.to_list(self._dl) + self._inflight
            self._dl = dl.init(self.cfg.io.queue_len)
            self._dl_dirty = False

    def _sync_inflight_to_device(self) -> None:
        for (t, slot, h, cls) in self._inflight:
            self._dl = dl.push(
                self._dl, jnp.asarray(t, I32),
                jnp.asarray([slot], I32),
                jnp.asarray([h], jnp.uint32),
                jnp.asarray([cls], I32), jnp.asarray(1, I32))
        self._inflight = []
        self._dl_dirty = True

    # -- jitted scan step ----------------------------------------------------
    def _make_step(self):
        cfg = self.cfg
        ecfg, iocfg = cfg.engine, cfg.io
        model, tree, depth = self.model, self.tree, self.tree_depth

        def step_fn(carry, chunk):
            state, queues, dline = carry
            ts = chunk["ts_us"].astype(I32)
            now = ts[-1]
            state, dline = dl.deliver(state, dline, now, ecfg.n_slots)
            batch = {k: chunk[k] for k in PKT_KEYS}
            state, out = de.process_batch_fast(state, batch, ecfg)
            granted = out["granted"]
            payload = chunk.get("payload", out["payload"])
            queues = vio.enqueue_device(queues, iocfg, granted,
                                        out["slot"], out["hash"], payload)
            span = jnp.maximum(ts[-1] - ts[0], 1)
            budget = vio.service_budget(span, ecfg.token_rate_per_us,
                                        iocfg.queue_len)
            queues, s2, h2, f2, cnt = vio.dequeue_device(queues, iocfg,
                                                         budget)
            cls = model.infer(f2)
            dline = dl.push(dline, now + cfg.loop_latency_us, s2, h2, cls,
                            cnt)
            verdict = out["verdict"]
            n_tree = jnp.asarray(0, I32)
            if tree is not None:
                from repro.core.data_engine.decision_tree import predict
                feats_now = jnp.stack(
                    [batch["pkt_len"].astype(I32),
                     jnp.zeros_like(batch["pkt_len"], I32)], axis=-1)
                pre = predict(tree, feats_now, depth)
                n_tree = jnp.sum((verdict < 0).astype(I32))
                verdict = jnp.where(verdict >= 0, verdict, pre)
            stats = jnp.stack([granted.sum().astype(I32), cnt,
                               jnp.sum((verdict >= 0).astype(I32)), n_tree])
            return (state, queues, dline), (verdict, stats)

        return step_fn

    def _ensure_jits(self) -> None:
        if self._scan_jit is None:
            step = self._make_step()
            self._scan_jit = jax.jit(functools.partial(jax.lax.scan, step))
            self._step_jit = jax.jit(step)

    # -- full-trace drivers --------------------------------------------------
    def run_trace(self, stream: Dict[str, np.ndarray],
                  labels_by_flow: Optional[np.ndarray] = None
                  ) -> Dict[str, np.ndarray]:
        """Feed a packet stream; returns per-packet verdicts.

        Fast mode with ``device_path`` runs the jitted scan driver; scan
        (exact) mode and ``device_path=False`` use the host loop.
        """
        cfg = self.cfg
        if not (cfg.fast_mode and cfg.device_path):
            return self._run_trace_host(stream)
        n = len(stream["ts_us"])
        B = cfg.batch_size
        arrs = {k: jnp.asarray(stream[k]) for k in PKT_KEYS}
        if self.oracle is not None and "flow_idx" in stream:
            from repro.data.synthetic_traffic import oracle_payloads
            pay = oracle_payloads(self.oracle, stream["flow_idx"],
                                  stream["flow_pos"], cfg.io.feat_len)
            arrs["payload"] = jnp.asarray(pay)
        self._sync_inflight_to_device()
        self._ensure_jits()
        n_chunks = n // B
        chunked = {k: v[:n_chunks * B].reshape((n_chunks, B)
                                               + v.shape[1:])
                   for k, v in arrs.items()}
        tail = ({k: v[n_chunks * B:] for k, v in arrs.items()}
                if n_chunks * B < n else None)
        carry = (self.state, self.queues, self._dl)
        cpe = cfg.control_plane_every
        verd_parts: List[np.ndarray] = []
        stat_sum = np.zeros(4, np.int64)
        for g in range(0, n_chunks, cpe):
            hi = min(g + cpe, n_chunks)
            window = {k: v[g:hi] for k, v in chunked.items()}
            carry, (vd, st) = self._scan_jit(carry, window)
            verd_parts.append(np.asarray(vd).reshape(-1))
            stat_sum += np.asarray(st, np.int64).sum(axis=0)
            self.state, self.queues, self._dl = carry
            if hi % cpe == 0:
                # the single host sync per control-plane window
                self.control_plane()
                carry = (self.state, self.queues, self._dl)
        n_batches = n_chunks
        if tail is not None:
            carry, (vd, st) = self._step_jit(carry, tail)
            verd_parts.append(np.asarray(vd))
            stat_sum += np.asarray(st, np.int64)
            self.state, self.queues, self._dl = carry
            n_batches += 1
            if n_batches % cpe == 0:
                self.control_plane()
        self._dl_dirty = True
        self.stats["packets"] += n
        self.stats["granted"] += int(stat_sum[0])
        self.stats["inferences"] += int(stat_sum[1])
        self.stats["classified_pkts"] += int(stat_sum[2])
        self.stats["tree_pkts"] += int(stat_sum[3])
        self.stats["dropped_q"] = int(self.queues["dropped"])
        self.stats["dropped_inflight"] = int(self._dl["dropped"])
        verdicts = (np.concatenate(verd_parts).astype(np.int32)
                    if verd_parts else np.full(n, -1, np.int32))
        return {"verdict": verdicts}

    def _run_trace_host(self, stream: Dict[str, np.ndarray]
                        ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        n = len(stream["ts_us"])
        verdicts = np.full(n, -1, np.int32)
        for i, start in enumerate(range(0, n, cfg.batch_size)):
            sl = slice(start, min(start + cfg.batch_size, n))
            batch = {k: v[sl] for k, v in stream.items()}
            out = self.step(batch)
            verdicts[sl] = out["verdict"]
            if (i + 1) % cfg.control_plane_every == 0:
                self.control_plane()
        return {"verdict": verdicts}
