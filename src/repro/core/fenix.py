"""FENIX end-to-end system: switch (Data Engine) + FPGA (Model Engine).

Co-simulation of the asynchronous hybrid (§3, Figure 2):

  packets --> Data Engine (flow tracking, probabilistic token bucket,
              ring buffers) --> mirror packets --> Vector I/O FIFO -->
              INT8 DNN inference --> (flow id, class) --> flow table cls

The Model Engine serves at most ``service_rate`` inferences per simulated
second (the paper's F in V=min(F, B/W)); results return to the switch with
``loop_latency_us`` (PCB interconnect, Fig. 11: 1-3us).  Flows with a
verdict are classified per-packet at line rate from the flow table; packets
of unclassified flows fall back to the switch decision tree.

Four trace drivers share the same semantics, selected by
``FenixConfig(driver=...)``:

* **device** (the ``driver="auto"`` default): ``run_trace`` chunks the
  stream into ``[n_chunks, batch_size]`` device arrays and runs ONE jitted
  ``lax.scan`` — Vector I/O enqueue/dequeue, the Model-Engine service
  budget, the loop-latency delay line, AND the control-plane LUT rebuild
  at each T_w window boundary (the ``"_cp"`` scan channel) are all array
  state inside the scan, so a replay issues zero host round trips
  (``FenixSystem.host_syncs`` stays 0).  Capture paths / TraceSpec traces
  stream through the same scan in double-buffered blocks: a producer
  thread parses and stages chunk k+1 while the device scans chunk k.
* **host** (``driver="host"``; ``exact=True`` for per-packet scan
  admission): the original batch-at-a-time ``step`` loop with Python-list
  in-flight results and an eager host-side control plane each window —
  kept as the bit-identity oracle the device drivers are tested against.

Multi-pipeline mode (``num_pipes=N``): a physical Tofino runs 2-4
independent ingress pipelines that all feed the one FPGA Model Engine.
The simulator mirrors that by sharding the whole switch side over a mesh
axis ``"pipe"``: packets route to pipes by the high bits of their flow-table
slot (``pipe_of_hash`` — slot-range partitioning, so the collision
structure matches the single-pipe table exactly), each pipe runs the Data
Engine on its own state slice under ``jax.shard_map`` (falling back to
``vmap`` when the host has fewer devices than pipes), per-pipe token
buckets refill at ``rate / num_pipes``, and the pipes' Vector I/O rings
drain into the single Model-Engine service budget through an
occupancy-weighted merge (``vio.pipe_shares``).  Verdicts return through
per-pipe delay lines — a scatter keyed by the owning pipe, no all-gather.
``num_pipes=1`` keeps the exact single-pipe driver; forcing
``driver="pipes"`` at ``num_pipes=1`` runs the sharded driver over a
1-device mesh and is bit-identical to it (asserted in
tests/test_multi_pipe.py).

Engine-farm mode (``num_engines=E``): E FPGA Model Engines behind the one
switch (§7 scale-out), sharded over an ``"engine"`` mesh axis orthogonal
to ``"pipe"`` (2-D ``farm_mesh``, nested-vmap fallback below P*E
devices).  Each engine owns an ingress FIFO and its own per-engine service
budget; the pipes' dequeued lanes are routed to the least-loaded engine by
free ingress space (``vio.engine_intake`` — the ``pipe_shares`` waterfall
with engines as consumers), and verdicts return through the owning pipe's
delay line tagged with the serving engine.  The switch's admission scales
with the pooled capacity (``farm_engine_config``: token rate x E).
``num_engines=1`` keeps the pipes/single drivers; forcing
``driver="farm"`` at ``num_engines=1`` is bit-identical to the pipes
driver (asserted in tests/test_engine_farm.py).
"""

from __future__ import annotations

import dataclasses
import functools
import os
import queue as queue_mod
import threading
import warnings
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

try:                                    # moved out of experimental in newer jax
    from jax import shard_map           # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map

from repro.core.data_engine import engine as de
from repro.core.data_engine import rate_limiter as rl
from repro.core.data_engine.state import (EngineConfig, farm_engine_config,
                                          hash_five_tuple, init_pipes_state,
                                          init_state, local_engine_config,
                                          pipe_of_hash)
from repro.core.model_engine import delay_line as dl
from repro.core.model_engine import engine_farm as farm
from repro.core.model_engine import vector_io as vio
from repro.core.model_engine.inference import EngineModel
from repro.core.data_engine import flow_tracker as ft
from repro.data.trace_ingest import TraceSpec

I32 = jnp.int32

# packet-stream fields consumed by the data plane
PKT_KEYS = ("src_ip", "dst_ip", "src_port", "dst_port", "proto",
            "ts_us", "pkt_len")

# run_trace drivers ("auto" resolves at FenixConfig construction)
DRIVER_NAMES = ("host", "device", "pipes", "farm")

# the pre-driver= boolean selector cube, kept as a deprecation shim
_LEGACY_KNOBS = ("fast_mode", "device_path", "pipes_path", "farm_path")


@dataclasses.dataclass
class FenixConfig:
    engine: EngineConfig = dataclasses.field(default_factory=EngineConfig)
    io: vio.IOConfig = dataclasses.field(default_factory=vio.IOConfig)
    batch_size: int = 512            # packets per data-engine step, per pipe
    loop_latency_us: int = 3         # switch->FPGA->switch (Fig. 11)
    control_plane_every: int = 8     # LUT refresh cadence (batches)
    # trace-driver selector — replaces the four interacting booleans
    # (fast_mode/device_path/pipes_path/farm_path) of earlier revisions:
    #   "auto"    farm if num_engines>1, else pipes if num_pipes>1, else
    #             host if exact=True, else device
    #   "host"    batch-at-a-time Python reference loop (the oracle every
    #             other driver is tested against)
    #   "device"  jitted single-pipe lax.scan, zero host syncs per window
    #   "pipes"   mesh-sharded multi-pipeline scan (forcing it at
    #             num_pipes=1 is bit-identical to "device")
    #   "farm"    2-D pipe x engine engine-farm scan (forcing it at
    #             num_engines=1 is bit-identical to "pipes")
    driver: str = "auto"
    # exact per-packet scan admission (reference semantics, slower).
    # Host driver only: the vectorized scan drivers require the fast
    # admission path.
    exact: bool = False
    # switch ingress pipelines sharing the one Model Engine; each pipe gets
    # 1/num_pipes of the slot space and of the token rate.  Power of two.
    num_pipes: int = 1
    # FPGA Model Engines behind the switch (§7 scale-out).  Each engine
    # serves at the full per-engine rate; admission scales with the pool.
    num_engines: int = 1
    # probability-gate backend override for EVERY driver path (host loop,
    # single-device scan, pipes, farm): "ref" | "pallas" | "pallas_tpu".
    # None keeps engine.gate_backend; a string replaces it, and the
    # derived per-pipe / pooled-farm configs inherit it, so one knob
    # switches the whole data plane.
    gate_backend: Optional[str] = None
    # serving model, used when FenixSystem is built without an explicit
    # model object: "bylen" (deterministic stand-in) or an int8_* name
    # from model_engine.serving.SERVING_MODELS (trained + quantized
    # traffic classifier running on kernels/int8_matmul).
    model: str = "bylen"
    # quantized-checkpoint directory (serving.save_quantized layout) the
    # int8 model is loaded from; None trains the CI-sized default once
    # per process.  Ignored for model="bylen".
    model_dir: Optional[str] = None
    # int8-GEMM backend for the Model Engine, the Model-Engine sibling of
    # gate_backend: "ref" | "pallas" | "pallas_tpu".  Applies to the
    # serving model — whether named here or passed to FenixSystem as an
    # EngineModel object (whose backend field it overrides).  Rejected
    # with model="bylen", which runs no GEMMs.
    matmul_backend: Optional[str] = None
    # ---- deprecated spellings (pre-driver= API) ---------------------------
    # None means "not passed".  Any explicit value is mapped onto
    # driver=/exact= in __post_init__ with a single DeprecationWarning per
    # construct, then cleared; new code must use driver=.
    fast_mode: Optional[bool] = None         # deprecated: use exact=
    device_path: Optional[bool] = None       # deprecated: use driver=
    pipes_path: Optional[bool] = None        # deprecated: use driver="pipes"
    farm_path: Optional[bool] = None         # deprecated: use driver="farm"

    def __post_init__(self):
        legacy = {k: getattr(self, k) for k in _LEGACY_KNOBS
                  if getattr(self, k) is not None}
        if legacy:
            if self.driver != "auto":
                raise ValueError(
                    "pass either driver= or the deprecated "
                    f"{sorted(legacy)} booleans, not both")
            warnings.warn(
                "FenixConfig(" + ", ".join(f"{k}={v}" for k, v in
                                           sorted(legacy.items()))
                + ") is deprecated; use FenixConfig(driver="
                  "\"auto\"|\"host\"|\"device\"|\"pipes\"|\"farm\") "
                  "(and exact=True for the per-packet scan-admission "
                  "host loop)", DeprecationWarning, stacklevel=3)
            fm = legacy.get("fast_mode", True)
            dp = legacy.get("device_path", True)
            use_farm = (self.farm_path if self.farm_path is not None
                        else self.num_engines > 1)
            use_pipes = (self.pipes_path if self.pipes_path is not None
                         else self.num_pipes > 1) or use_farm
            if use_pipes and not (fm and dp):
                raise ValueError(
                    "the sharded drivers run the vectorized device scan "
                    "only: FenixConfig(driver=\"pipes\"|\"farm\") cannot "
                    "be combined with the deprecated fast_mode=False / "
                    "device_path=False spellings")
            if use_farm:
                self.driver = "farm"
            elif use_pipes:
                self.driver = "pipes"
            elif fm and dp:
                self.driver = "device"
            else:
                self.driver = "host"
                self.exact = self.exact or not fm
            self.fast_mode = self.device_path = None
            self.pipes_path = self.farm_path = None
        if self.driver == "auto":
            self.driver = ("farm" if self.num_engines > 1 else
                           "pipes" if self.num_pipes > 1 else
                           "host" if self.exact else "device")
        if self.driver not in DRIVER_NAMES:
            raise ValueError(
                f"unknown driver {self.driver!r}; pick one of "
                f"{('auto',) + DRIVER_NAMES}")
        if self.num_engines > 1 and self.driver != "farm":
            raise ValueError(
                f"num_engines={self.num_engines} needs the engine-farm "
                f"scan: use FenixConfig(driver=\"farm\") (a multi-engine "
                f"pool cannot run on driver={self.driver!r})")
        if self.num_pipes > 1 and self.driver not in ("pipes", "farm"):
            raise ValueError(
                f"num_pipes={self.num_pipes} needs a sharded driver: use "
                f"FenixConfig(driver=\"pipes\") or driver=\"farm\" (not "
                f"driver={self.driver!r})")
        if self.exact and self.driver != "host":
            raise ValueError(
                "exact=True (per-packet scan admission) runs only on the "
                "reference loop: use FenixConfig(driver=\"host\", "
                f"exact=True), not driver={self.driver!r}")


def pipe_mesh(num_pipes: int) -> Optional[Mesh]:
    """1-D device mesh over the ``"pipe"`` axis, or None for vmap fallback.

    One device per pipeline (the first ``num_pipes`` of ``jax.devices()`` —
    on CPU CI these are the ``--xla_force_host_platform_device_count``
    virtual devices).  Hosts with fewer devices than pipes run the same
    per-pipe functions under ``vmap`` on one device instead.
    """
    devs = jax.devices()
    if len(devs) >= num_pipes:
        return Mesh(np.asarray(devs[:num_pipes]), ("pipe",))
    return None


def _make_pipe_local(lcfg: EngineConfig, iocfg: vio.IOConfig, tree,
                     depth: int):
    """The pipe-local half of a multi-pipe step: everything that touches
    only one pipeline's registers — delay-line delivery, the Data Engine,
    the local Vector I/O enqueue, and the switch-tree verdict fill.  Pure
    per-shard function: runs unchanged under ``shard_map`` or ``vmap``.
    """

    def de_local(state, queues, dline, chunk):
        ts = chunk["ts_us"].astype(I32)
        now = ts[-1]
        state, dline = dl.deliver(state, dline, now, lcfg.n_slots)
        batch = {k: chunk[k] for k in PKT_KEYS}
        state, out = de.process_batch_fast(state, batch, lcfg)
        payload = chunk.get("payload", out["payload"])
        queues = vio.enqueue_device(queues, iocfg, out["granted"],
                                    out["slot"], out["hash"], payload)
        verdict = out["verdict"]
        n_tree = jnp.asarray(0, I32)
        if tree is not None:
            from repro.core.data_engine.decision_tree import predict
            feats_now = jnp.stack(
                [batch["pkt_len"].astype(I32),
                 jnp.zeros_like(batch["pkt_len"], I32)], axis=-1)
            pre = predict(tree, feats_now, depth)
            n_tree = jnp.sum((verdict < 0).astype(I32))
            verdict = jnp.where(verdict >= 0, verdict, pre)
        aux = {"verdict": verdict, "now": now, "ts_first": ts[0],
               "granted": out["granted"].sum().astype(I32),
               "classified": jnp.sum((verdict >= 0).astype(I32)),
               "n_tree": n_tree}
        return state, queues, dline, aux

    return de_local


def _make_single_step(ecfg: EngineConfig, iocfg: vio.IOConfig,
                      loop_latency_us: int, model, tree, depth: int):
    """One scan step of the single-pipe device driver: the pipe-local body
    plus the full-budget service epilogue (dequeue, inference, delay-line
    push).

    Also the per-pipe *tail* step of the multi-pipe driver (with the local
    ``EngineConfig``): a pipe whose stream outlasts the uniform scan
    finishes its trailing batch through this function, draining only its
    own ring with its own 1/P budget share.

    The chunk's ``"_cp"`` flag marks a T_w window boundary: the step then
    folds the control-plane LUT rebuild + window reset into the scan carry
    (``lax.cond`` after the service epilogue — the position the host
    oracle applies it at, between batches), so a full trace replays with
    zero host round trips.  Tail batches driven by the sharded drivers
    pass ``_cp=False`` and roll the stacked window outside instead.
    """
    de_local = _make_pipe_local(ecfg, iocfg, tree, depth)

    def step_fn(carry, chunk):
        state, queues, dline = carry
        state, queues, dline, aux = de_local(state, queues, dline, chunk)
        budget = vio.step_budget(aux["ts_first"], aux["now"],
                                 ecfg.token_rate_per_us, iocfg.queue_len)
        queues, s2, h2, f2, cnt = vio.dequeue_device(queues, iocfg,
                                                     budget)
        cls = model.infer(f2)
        dline = dl.push(dline, aux["now"] + loop_latency_us, s2, h2, cls,
                        cnt)
        state = jax.lax.cond(
            chunk["_cp"], lambda s: rl.control_plane_update(s, ecfg),
            lambda s: s, state)
        stats = jnp.stack([aux["granted"], cnt, aux["classified"],
                           aux["n_tree"]])
        return (state, queues, dline), (aux["verdict"], stats)

    return step_fn


def _make_pipes_step(cfg: "FenixConfig", lcfg: EngineConfig, model, tree,
                     depth: int, mesh: Optional[Mesh], masked: bool):
    """One scan step of the multi-pipe driver: sharded Data Engines feeding
    the single Model Engine.

    The whole step is a per-shard function over the ``"pipe"`` axis — run
    under ``shard_map`` on the mesh, or under ``vmap(axis_name="pipe")``
    when the host has fewer devices than pipes.  The cross-pipeline merge
    exchanges *scalars only*: each pipe all-gathers one packed
    [occupancy, batch-start, batch-end] vector (a single collective per
    step), derives the single Model-Engine budget (global service rate
    over the union time span, capped by the pipes' total ring capacity)
    and its own occupancy-weighted share of it, then drains its ring, runs
    its share of inference, and pushes results into its own delay line —
    feature lanes and verdicts never cross pipes.

    ``masked=True`` compiles the skew variant: a pipe whose stream is
    already exhausted (``_active`` false) replays a dummy batch with its
    state frozen, zero merge weight, and discarded stats — as if the step
    never ran.  The driver uses it only for scan windows that actually
    contain frozen steps; fully-active windows take the unmasked variant
    with no select overhead.
    """
    iocfg, num_pipes = cfg.io, cfg.num_pipes
    de_local = _make_pipe_local(lcfg, iocfg, tree, depth)
    imax = jnp.iinfo(jnp.int32)

    def pipe_step(state, queues, dline, chunk):
        # one pipe's slice, plain single-pipe shapes
        if masked:
            active = chunk["_active"]
            chunk = {k: v for k, v in chunk.items() if k != "_active"}
        new_state, new_queues, new_dline, aux = de_local(state, queues,
                                                         dline, chunk)
        if masked:
            state, queues, dline = jax.tree.map(
                lambda nu, old: jnp.where(active, nu, old),
                (new_state, new_queues, new_dline),
                (state, queues, dline))
            occ_self = (queues["tail"] - queues["head"]) \
                * active.astype(I32)
            lo_self = jnp.where(active, aux["ts_first"], imax.max)
            hi_self = jnp.where(active, aux["now"], imax.min)
        else:
            state, queues, dline = new_state, new_queues, new_dline
            occ_self = queues["tail"] - queues["head"]
            lo_self, hi_self = aux["ts_first"], aux["now"]
        gath = jax.lax.all_gather(
            jnp.stack([occ_self, lo_self, hi_self]), "pipe")    # [P, 3]
        budget = vio.step_budget(jnp.min(gath[:, 1]),
                                 jnp.max(gath[:, 2]),
                                 cfg.engine.token_rate_per_us,
                                 num_pipes * iocfg.queue_len)
        share = vio.pipe_shares(gath[:, 0],
                                budget)[jax.lax.axis_index("pipe")]
        queues, s2, h2, f2, cnt = vio.dequeue_device(queues, iocfg, share)
        cls = model.infer(f2)
        dline = dl.push(dline, aux["now"] + cfg.loop_latency_us, s2, h2,
                        cls, cnt)
        # in-scan control plane at T_w boundaries (applies to frozen pipes
        # too — the host oracle rolled every pipe's window, active or not)
        state = jax.lax.cond(
            chunk["_cp"], lambda s: rl.control_plane_update(s, lcfg),
            lambda s: s, state)
        stats = jnp.stack([aux["granted"], cnt, aux["classified"],
                           aux["n_tree"]])
        if masked:
            stats = stats * active.astype(I32)
        return state, queues, dline, aux["verdict"], stats

    if mesh is not None:
        def shard_body(state, queues, dline, chunk):
            args = jax.tree.map(lambda x: x[0], (state, queues, dline,
                                                 chunk))
            out = pipe_step(*args)
            return jax.tree.map(lambda x: jnp.asarray(x)[None], out)

        stage = shard_map(shard_body, mesh=mesh,
                          in_specs=PartitionSpec("pipe"),
                          out_specs=PartitionSpec("pipe"),
                          # pallas_call (the fused rate gate) has no
                          # replication rule; every spec here is fully
                          # partitioned over "pipe" anyway, so the static
                          # replication checker adds nothing
                          check_rep=False)
    else:
        stage = jax.vmap(pipe_step, axis_name="pipe")

    def step_fn(carry, chunk):
        states, queues, dls = carry
        states, queues, dls, verdict, stats = stage(states, queues, dls,
                                                    chunk)
        return (states, queues, dls), (verdict, stats.sum(axis=0))

    return step_fn


class FenixSystem:
    """Stateful co-simulation wrapper.

    ``oracle_windows``: optional (flow_feats_list) used in fast mode — the
    vectorized data plane collapses same-flow packets within a batch, so the
    simulator reconstructs each granted packet's ring window from ground
    truth ((flow_idx, flow_pos) -> F1..F9), which is exactly the window the
    sequential switch pipeline would hold.  Scan mode builds windows from
    the simulated ring itself.
    """

    def __init__(self, cfg: FenixConfig, model: Optional[EngineModel] = None,
                 tree: Optional[Dict] = None, tree_depth: int = 4,
                 oracle_windows: Optional[List[np.ndarray]] = None,
                 n_est: float = 1000.0, q_est_pps: float = 1e6):
        from repro.core.model_engine import serving
        from repro.kernels.rate_gate.ops import validate_backend

        if cfg.gate_backend is not None:
            cfg = dataclasses.replace(
                cfg, engine=dataclasses.replace(
                    cfg.engine, gate_backend=cfg.gate_backend))
        validate_backend(cfg.engine.gate_backend)
        if model is None:
            # resolve the config's serving-model name (trains/loads the
            # quantized classifier for int8_* names; see serving.py)
            model = serving.build_model(cfg.model,
                                        matmul_backend=cfg.matmul_backend,
                                        model_dir=cfg.model_dir)
        elif cfg.matmul_backend is not None:
            # explicit model object: the config knob still wins, so one
            # FenixConfig switch flips every driver of a conformance run
            from repro.kernels.int8_matmul.ops import (
                validate_backend as validate_matmul)
            validate_matmul(cfg.matmul_backend)
            if not isinstance(model, EngineModel):
                raise ValueError(
                    "matmul_backend applies to quantized EngineModels; "
                    f"got {type(model).__name__}")
            model = dataclasses.replace(model, backend=cfg.matmul_backend)
        self.cfg = cfg
        self.model = model
        self.tree = tree
        self.tree_depth = tree_depth
        self.oracle = oracle_windows
        # initial control-plane estimates for the probability LUT (rebuilt
        # from observed window stats at every T_w rollover); (0, 0) builds
        # the saturated P=1 gate — admission limited only by the token
        # bucket, which the oversubscription benchmarks use to hold the
        # Model-Engine farm at exactly its service capacity
        self.n_est = n_est
        self.q_est_pps = q_est_pps
        # driver dispatch (FenixConfig.__post_init__ already resolved
        # "auto" and validated the knob combinations); the farm rides on
        # the pipes state layout, so it implies the sharded paths
        self._use_farm = cfg.driver == "farm"
        self._use_pipes = cfg.driver in ("pipes", "farm")
        # switch-side view of the engine pool: admission at E x one engine
        self.gcfg = farm_engine_config(cfg.engine, cfg.num_engines)
        self.lcfg = local_engine_config(self.gcfg, cfg.num_pipes)
        if self._use_farm:
            self._mesh = farm.farm_mesh(cfg.num_pipes, cfg.num_engines)
        elif self._use_pipes:
            self._mesh = pipe_mesh(cfg.num_pipes)
        else:
            self._mesh = None
        self._scan_jit = None
        self._step_jit = None
        self._pipe_scan_jit = None
        self._pipe_scan_masked_jit = None
        self._pipe_tail_jit = None
        self._farm_scan_jit = None
        self._farm_scan_masked_jit = None
        self._farm_tail_jit = None
        self._cp_pipes_jit = None
        self.reset()

    def reset(self) -> None:
        """Fresh run state (tables, queues, delay lines, stats); compiled
        step functions are kept, so repeated traces skip recompilation."""
        cfg = self.cfg
        self.state = init_state(cfg.engine, n_est=self.n_est,
                                q_est_pps=self.q_est_pps)
        self.queues = vio.init_queues(cfg.io)
        self.stats = {"packets": 0, "granted": 0, "inferences": 0,
                      "classified_pkts": 0, "tree_pkts": 0, "dropped_q": 0,
                      # results dropped by the fixed-capacity device delay
                      # line (always 0 on the host path, whose in-flight
                      # list is unbounded; nonzero here flags that the
                      # device run diverged and io.queue_len needs raising)
                      "dropped_inflight": 0,
                      # engine-farm plumbing (single-engine paths keep the
                      # degenerate E=1 values so stats dicts stay
                      # comparable across drivers): inferences served by
                      # each Model Engine, lanes dropped at engine ingress
                      # (0 unless the router is broken — it is
                      # capacity-aware), and per-engine log2 histograms of
                      # post-service ingress queue depth, one sample per
                      # batch round
                      "served_per_engine": [0] * cfg.num_engines,
                      "dropped_eq": 0,
                      "engine_q_depth_hist": [[0] * farm.DEPTH_BUCKETS
                                              for _ in
                                              range(cfg.num_engines)]}
        # host-driven control-plane round trips this run: stays 0 on the
        # device/pipes/farm drivers (their LUT rebuild runs inside the
        # scan); each host-loop T_w rollover counts 1.  Kept outside
        # ``stats`` so stats dicts stay bit-comparable across drivers.
        self.host_syncs = 0
        # in-flight inference results, host view: (deliver_ts, slot, h, cls)
        self._inflight: List[Tuple[int, int, int, int]] = []
        # ... and the equivalent device-resident delay line
        self._dl = dl.init(cfg.io.queue_len)
        self._dl_dirty = False
        if self._use_pipes:
            # stacked [num_pipes, ...] switch state + per-pipe FIFOs/lines
            # (admission from the pooled-engine view; E=1 degenerates to
            # cfg.engine, so the pipes driver is untouched)
            self.pstate = init_pipes_state(self.gcfg, cfg.num_pipes,
                                           n_est=self.n_est,
                                           q_est_pps=self.q_est_pps)
            self.pqueues = vio.init_pipes_queues(cfg.io, cfg.num_pipes)
            # a pipe can receive up to E engines' worth of results per
            # step, so the farm scales the per-pipe delay line with E
            self.pdl = dl.init_pipes(cfg.io.queue_len * cfg.num_engines,
                                     cfg.num_pipes)
        if self._use_farm:
            # per-engine ingress FIFOs on the FPGA side of the interconnect
            self.eq = vio.init_engine_queues(cfg.io, cfg.num_engines,
                                             cfg.num_pipes)

    # -- one simulation step (host reference path) --------------------------
    def step(self, packets: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Process one packet batch; returns per-packet verdicts + masks."""
        cfg = self.cfg
        if self._use_pipes:
            raise RuntimeError(
                "step() drives the single-pipe host state, which the "
                "sharded/farm drivers do not maintain; use run_trace() "
                "with driver=\"pipes\" / driver=\"farm\"")
        self._sync_inflight_to_host()
        n = len(packets["ts_us"])
        batch = {k: jnp.asarray(v) for k, v in packets.items()
                 if k in PKT_KEYS}
        now = int(packets["ts_us"][-1])
        # deliver finished inferences whose latency elapsed
        self._deliver(now)
        if not cfg.exact:
            self.state, out = de.process_batch_fast(self.state, batch,
                                                    cfg.engine)
        else:
            self.state, out = de.process_batch(self.state, batch, cfg.engine,
                                               tree=self.tree,
                                               tree_depth=self.tree_depth)
        granted = np.asarray(out["granted"])
        slots = np.asarray(out["slot"])[granted]
        hashes = np.asarray(out["hash"])[granted]
        feats = np.asarray(out["payload"])[granted]
        if not cfg.exact and self.oracle is not None and \
                "flow_idx" in packets:
            from repro.data.synthetic_traffic import ring_window
            fi = packets["flow_idx"][granted]
            fp = packets["flow_pos"][granted]
            win = feats.shape[1]
            feats = np.stack([
                ring_window(self.oracle[int(a)], int(b), win)
                for a, b in zip(fi, fp)]) if len(fi) else feats
        self.queues = vio.enqueue_batch(self.queues, cfg.io, slots, hashes,
                                        feats)
        # model engine serves a batch bounded by its service rate V (the
        # span->budget composition is vio.step_budget, shared with the
        # device scan and the multi-pipe merge so all paths agree exactly)
        budget = int(vio.step_budget(int(packets["ts_us"][0]),
                                     int(packets["ts_us"][-1]),
                                     cfg.engine.token_rate_per_us,
                                     cfg.io.queue_len))
        self.queues, s2, h2, f2 = vio.dequeue_batch(self.queues, cfg.io,
                                                    budget)
        if len(s2):
            cls = np.asarray(self.model.infer(jnp.asarray(f2)))
            for i in range(len(s2)):
                self._inflight.append((now + cfg.loop_latency_us,
                                       int(s2[i]), int(h2[i]), int(cls[i])))
            self.stats["inferences"] += len(s2)
            self.stats["served_per_engine"][0] += len(s2)
        # verdicts: flow-table class (post-delivery) else switch tree
        verdict = np.asarray(out["verdict"])
        if self.tree is not None and not cfg.exact:
            from repro.core.data_engine.decision_tree import predict
            feats_now = np.stack([packets["pkt_len"],
                                  np.zeros(n, np.int32)], axis=-1)
            pre = np.asarray(predict(self.tree, jnp.asarray(feats_now),
                                     self.tree_depth))
            verdict = np.where(verdict >= 0, verdict, pre)
            self.stats["tree_pkts"] += int(np.sum(np.asarray(
                out["verdict"]) < 0))
        self.stats["packets"] += n
        self.stats["granted"] += int(granted.sum())
        self.stats["classified_pkts"] += int(np.sum(verdict >= 0))
        self.stats["dropped_q"] = int(self.queues["dropped"])
        # one depth sample per batch round; no engine queues on this path
        self.stats["engine_q_depth_hist"][0][0] += 1
        return {"verdict": verdict, "granted": granted,
                "slot": np.asarray(out["slot"])}

    def _deliver(self, now: int) -> None:
        remain = []
        for (t, slot, h, cls) in self._inflight:
            if t <= now:
                self.state = ft.apply_inference_result(
                    self.state, jnp.asarray(slot),
                    jnp.asarray(cls), jnp.asarray(h, jnp.uint32))
            else:
                remain.append((t, slot, h, cls))
        self._inflight = remain

    def control_plane(self) -> None:
        """T_w rollover driven from the host loop: LUT refresh from the
        observed (N, Q) window counters + window reset.

        Runs the exact same ``rl.control_plane_update`` the device drivers
        fold into their scans — this host-driven invocation is the
        bit-identity oracle for the in-scan rebuild, and each call counts
        one host-side control-plane round trip in ``host_syncs`` (always 0
        on the device/pipes/farm drivers)."""
        self.host_syncs += 1
        new = rl.control_plane_update(self.state, self.cfg.engine)
        # run eagerly, the update aliases leaves (win_start IS t_last when
        # t_last is already int32; the zeroed window counters can share a
        # cached constant) — and the donated device scans reject donating
        # one buffer twice, so re-materialize the scalar leaves
        self.state = {k: (jnp.array(v) if getattr(v, "ndim", 1) == 0
                          else v) for k, v in new.items()}

    def control_plane_pipes(self) -> None:
        """T_w rollover across pipes, host-driven: one LUT per pipe from
        that pipe's own (N, Q) window counters, each anchored at the pipe's
        own clock.  Oracle path only — the sharded scans roll their
        windows in-scan (``"_cp"``) without coming here."""
        self.host_syncs += 1
        self.pstate = rl.control_plane_update_pipes(self.pstate, self.lcfg,
                                                    self.cfg.num_pipes)

    # -- in-flight state interop (host list <-> device delay line) ----------
    def _sync_inflight_to_host(self) -> None:
        if self._dl_dirty:
            self._inflight = dl.to_list(self._dl) + self._inflight
            self._dl = dl.init(self.cfg.io.queue_len)
            self._dl_dirty = False

    def _sync_inflight_to_device(self) -> None:
        for (t, slot, h, cls) in self._inflight:
            self._dl = dl.push(
                self._dl, jnp.asarray(t, I32),
                jnp.asarray([slot], I32),
                jnp.asarray([h], jnp.uint32),
                jnp.asarray([cls], I32), jnp.asarray(1, I32))
        self._inflight = []
        self._dl_dirty = True

    # -- jitted scan step ----------------------------------------------------
    def _ensure_jits(self) -> None:
        if self._scan_jit is None:
            step = _make_single_step(self.cfg.engine, self.cfg.io,
                                     self.cfg.loop_latency_us, self.model,
                                     self.tree, self.tree_depth)
            # the carry is donated: each scan/step call re-feeds the
            # previous call's output carry, so the streaming driver can
            # reuse the state/queue/delay-line buffers in place
            self._scan_jit = jax.jit(functools.partial(jax.lax.scan, step),
                                     donate_argnums=(0,))
            self._step_jit = jax.jit(step, donate_argnums=(0,))

    def _ensure_pipe_jits(self) -> None:
        if self._pipe_scan_jit is None:
            def mk(masked):
                return jax.jit(functools.partial(
                    jax.lax.scan,
                    _make_pipes_step(self.cfg, self.lcfg, self.model,
                                     self.tree, self.tree_depth,
                                     self._mesh, masked)))

            self._pipe_scan_jit = mk(False)
            self._pipe_scan_masked_jit = mk(True)
            tail = _make_single_step(self.lcfg, self.cfg.io,
                                     self.cfg.loop_latency_us, self.model,
                                     self.tree, self.tree_depth)
            self._pipe_tail_jit = jax.jit(tail)
            self._ensure_cp_pipes_jit()

    def _ensure_cp_pipes_jit(self) -> None:
        # stacked-state window rollover for batch rounds that end outside
        # the scan (per-pipe tails): jitted dispatch, no host round trip
        if self._cp_pipes_jit is None:
            self._cp_pipes_jit = jax.jit(
                lambda st: rl.control_plane_update_pipes(st, self.lcfg))

    def _ensure_farm_jits(self) -> None:
        if self._farm_scan_jit is None:
            cfg = self.cfg
            de_local = _make_pipe_local(self.lcfg, cfg.io, self.tree,
                                        self.tree_depth)
            # per-engine budgets use the SINGLE-engine rate; their sum is
            # the pooled admission rate baked into self.gcfg / self.lcfg
            base_rate = cfg.engine.token_rate_per_us
            def mk(masked):
                return jax.jit(functools.partial(
                    jax.lax.scan,
                    farm.make_farm_step(cfg.num_pipes, cfg.num_engines,
                                        cfg.io, base_rate,
                                        cfg.loop_latency_us, de_local,
                                        self.model, self._mesh, masked,
                                        local_cfg=self.lcfg)))

            self._farm_scan_jit = mk(False)
            self._farm_scan_masked_jit = mk(True)
            self._farm_tail_jit = jax.jit(farm.make_farm_tail(
                cfg.num_pipes, cfg.num_engines, cfg.io, base_rate,
                cfg.loop_latency_us, de_local, self.model))
            self._ensure_cp_pipes_jit()

    # -- full-trace drivers --------------------------------------------------
    def run_trace(self, trace=None, *, stream=None, labels_by_flow=None,
                  source=None, adapter=None, trace_labels="auto",
                  limit: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Feed a packet stream; returns per-packet verdicts.

        ``trace`` is one of:

        * a packet-stream dict (``synthetic_traffic.packet_stream`` or
          ``trace_ingest.load_stream`` output),
        * a capture path — raw pcap or CSV, ingested through
          :mod:`repro.data.trace_ingest` with default settings, or
        * a :class:`repro.data.trace_ingest.TraceSpec` naming the capture
          plus its adapter / labels / limit / chunking / overlap options.

        Path and TraceSpec traces run the streaming driver on
        ``driver="device"``: a producer thread parses the next capture
        chunk and stages it on device while the scan consumes the current
        one, so parse time hides under compute
        (``TraceSpec(overlap=False)`` forces synchronous staging).  The
        sharded drivers route packets to pipes globally, so they load the
        capture fully first; the host loop does too.

        The pre-TraceSpec keywords (``stream=``, ``source=``,
        ``adapter=``, ``trace_labels=``, ``limit=``, ``labels_by_flow=``)
        are deprecated spellings of the same thing and map onto
        ``trace=``.
        """
        trace = self._resolve_trace(trace, stream, labels_by_flow, source,
                                    adapter, trace_labels, limit)
        if isinstance(trace, TraceSpec) and self.cfg.driver == "device" \
                and self.oracle is None:
            return self._run_trace_device_stream(trace)
        stream = trace if isinstance(trace, dict) else trace.load()
        if self._use_pipes:
            return self._run_trace_pipes(stream)
        if self.cfg.driver == "host":
            return self._run_trace_host(stream)
        return self._run_trace_device(stream)

    def _resolve_trace(self, trace, stream, labels_by_flow, source,
                       adapter, trace_labels, limit):
        """Map run_trace's argument surface onto one dict-or-TraceSpec."""
        used = [name for name, passed in
                (("stream", stream is not None),
                 ("source", source is not None),
                 ("adapter", adapter is not None),
                 ("trace_labels", trace_labels != "auto"),
                 ("limit", limit is not None),
                 ("labels_by_flow", labels_by_flow is not None)) if passed]
        if used:
            warnings.warn(
                "run_trace(" + "=..., ".join(used) + "=...) is "
                "deprecated; pass run_trace(trace=<packet-stream dict | "
                "capture path | TraceSpec>)", DeprecationWarning,
                stacklevel=3)
        given = [t for t in (trace, stream, source) if t is not None]
        if len(given) != 1:
            raise ValueError(
                "run_trace needs exactly one trace: trace= (a "
                "packet-stream dict, a capture path, or a TraceSpec); "
                "stream=/source= are its deprecated spellings")
        trace = given[0]
        if isinstance(trace, (dict, TraceSpec)):
            return trace
        # a capture path (or open file object): wrap it, folding in any
        # deprecated per-call options
        return TraceSpec(trace, adapter=adapter, labels=trace_labels,
                         limit=limit)

    def _accum_device_stats(self, n: int, n_batches: int,
                            stat_sum: np.ndarray) -> None:
        self.stats["packets"] += n
        self.stats["granted"] += int(stat_sum[0])
        self.stats["inferences"] += int(stat_sum[1])
        self.stats["classified_pkts"] += int(stat_sum[2])
        self.stats["tree_pkts"] += int(stat_sum[3])
        self.stats["dropped_q"] = int(self.queues["dropped"])
        self.stats["dropped_inflight"] = int(self._dl["dropped"])
        self.stats["served_per_engine"][0] += int(stat_sum[1])
        self.stats["engine_q_depth_hist"][0][0] += n_batches

    def _run_trace_device(self, stream: Dict[str, np.ndarray]
                          ) -> Dict[str, np.ndarray]:
        """Single-pipe device driver, in-memory trace: ONE jitted
        ``lax.scan`` over every full chunk, with the control-plane LUT
        rebuild folded into the scan at T_w boundaries (the ``"_cp"``
        channel) — zero host syncs regardless of trace length."""
        cfg = self.cfg
        n = len(stream["ts_us"])
        B, cpe = cfg.batch_size, cfg.control_plane_every
        arrs = {k: jnp.asarray(stream[k]) for k in PKT_KEYS}
        if self.oracle is not None and "flow_idx" in stream:
            from repro.data.synthetic_traffic import oracle_payloads
            pay = oracle_payloads(self.oracle, stream["flow_idx"],
                                  stream["flow_pos"], cfg.io.feat_len)
            arrs["payload"] = jnp.asarray(pay)
        self._sync_inflight_to_device()
        self._ensure_jits()
        n_chunks = n // B
        chunked = {k: v[:n_chunks * B].reshape((n_chunks, B)
                                               + v.shape[1:])
                   for k, v in arrs.items()}
        chunked["_cp"] = jnp.asarray(
            (np.arange(1, n_chunks + 1) % cpe) == 0)
        tail = ({k: v[n_chunks * B:] for k, v in arrs.items()}
                if n_chunks * B < n else None)
        carry = (self.state, self.queues, self._dl)
        verd_parts: List[jax.Array] = []
        stat_sum = np.zeros(4, np.int64)
        if n_chunks:
            carry, (vd, st) = self._scan_jit(carry, chunked)
            verd_parts.append(vd.reshape(-1))
            stat_sum += np.asarray(st).astype(np.int64).sum(axis=0)
        n_batches = n_chunks
        if tail is not None:
            n_batches += 1
            tail["_cp"] = jnp.asarray(n_batches % cpe == 0)
            carry, (vd, st) = self._step_jit(carry, tail)
            verd_parts.append(vd)
            stat_sum += np.asarray(st).astype(np.int64)
        self.state, self.queues, self._dl = carry
        self._dl_dirty = True
        self._accum_device_stats(n, n_batches, stat_sum)
        verdicts = (np.concatenate([np.asarray(v) for v in verd_parts])
                    .astype(np.int32) if verd_parts
                    else np.full(n, -1, np.int32))
        return {"verdict": verdicts}

    # chunks staged per streaming block: control_plane_every scan steps x
    # this many windows — big enough to amortize dispatch, small enough
    # that double-buffering two in-flight blocks stays cheap
    _STAGE_WINDOWS = 4

    def _run_trace_device_stream(self, spec: TraceSpec
                                 ) -> Dict[str, np.ndarray]:
        """Single-pipe device driver over a capture that is never fully
        resident: consume fixed-shape [W, B] blocks as a producer stages
        them (``TraceSpec.overlap`` double-buffers parse + ``device_put``
        in a background thread; ``overlap=False`` stages synchronously
        between scans).  The in-scan ``"_cp"`` control plane carries over
        unchanged — still zero host syncs, and the donated carry lets
        consecutive blocks reuse the same state buffers."""
        self._sync_inflight_to_device()
        self._ensure_jits()
        carry = (self.state, self.queues, self._dl)
        verd_parts: List[jax.Array] = []
        stat_sum = np.zeros(4, np.int64)
        n = 0
        n_batches = 0
        B = self.cfg.batch_size
        for kind, block in self._staged_blocks(spec):
            if kind == "block":
                steps = block["_cp"].shape[0]
                carry, (vd, st) = self._scan_jit(carry, block)
                verd_parts.append(vd.reshape(-1))
                stat_sum += np.asarray(st).astype(np.int64).sum(axis=0)
                n += steps * B
                n_batches += steps
            else:                                   # trailing < B packets
                n += int(block["ts_us"].shape[0])
                n_batches += 1
                carry, (vd, st) = self._step_jit(carry, block)
                verd_parts.append(vd)
                stat_sum += np.asarray(st).astype(np.int64)
        self.state, self.queues, self._dl = carry
        self._dl_dirty = True
        self._accum_device_stats(n, n_batches, stat_sum)
        verdicts = (np.concatenate([np.asarray(v) for v in verd_parts])
                    .astype(np.int32) if verd_parts
                    else np.full(0, -1, np.int32))
        return {"verdict": verdicts}

    def _stage_gen(self, spec: TraceSpec):
        """Parse the capture chunk-wise and re-batch it into staged
        ("block", {[W, B] columns + "_cp" [W]}) items plus one final
        ("tail", {[<B] columns + scalar "_cp"}).  Each item is already on
        device (``jax.device_put``) when yielded — this is the half the
        ingest thread overlaps with the scans."""
        B, cpe = self.cfg.batch_size, self.cfg.control_plane_every
        W = cpe * self._STAGE_WINDOWS
        pend = {k: [] for k in PKT_KEYS}
        pend_n = 0
        chunk_i = 0                     # global batch counter, drives _cp

        def emit(cols, steps):
            nonlocal chunk_i
            block = {k: jax.device_put(
                np.ascontiguousarray(cols[k][:steps * B])
                .reshape(steps, B)) for k in PKT_KEYS}
            block["_cp"] = jax.device_put(
                (np.arange(chunk_i + 1, chunk_i + steps + 1) % cpe) == 0)
            chunk_i += steps
            return "block", block

        for raw in spec.iter_chunks():
            for k in PKT_KEYS:
                pend[k].append(np.asarray(raw[k]))
            pend_n += len(raw["ts_us"])
            while pend_n >= W * B:
                cols = {k: np.concatenate(pend[k]) for k in PKT_KEYS}
                yield emit(cols, W)
                pend = {k: [cols[k][W * B:]] for k in PKT_KEYS}
                pend_n -= W * B
        if pend_n:
            cols = {k: np.concatenate(pend[k]) for k in PKT_KEYS}
            steps = pend_n // B
            if steps:
                yield emit(cols, steps)
            if pend_n > steps * B:
                tail = {k: jax.device_put(cols[k][steps * B:])
                        for k in PKT_KEYS}
                tail["_cp"] = jax.device_put(
                    np.bool_((chunk_i + 1) % cpe == 0))
                yield "tail", tail

    def _staged_blocks(self, spec: TraceSpec):
        """Yield `_stage_gen` items, double-buffered through a bounded
        queue when ``spec.overlap`` — the producer thread parses and
        stages block k+1 while the caller scans block k."""
        gen = self._stage_gen(spec)
        if not spec.overlap:
            yield from gen
            return
        q: queue_mod.Queue = queue_mod.Queue(maxsize=2)
        stop = threading.Event()
        err: List[BaseException] = []

        def produce():
            try:
                for item in gen:
                    while not stop.is_set():
                        try:
                            q.put(item, timeout=0.1)
                            break
                        except queue_mod.Full:
                            continue
                    if stop.is_set():
                        return
            except BaseException as e:  # surfaced on the consumer side
                err.append(e)
            finally:
                while not stop.is_set():    # sentinel, unless aborting
                    try:
                        q.put(None, timeout=0.1)
                        break
                    except queue_mod.Full:
                        continue

        t = threading.Thread(target=produce, daemon=True,
                             name="fenix-trace-ingest")
        t.start()
        try:
            while True:
                item = q.get()
                if item is None:
                    break
                yield item
        finally:
            stop.set()
            t.join()
        if err:
            raise err[0]

    def _run_trace_host(self, stream: Dict[str, np.ndarray]
                        ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        n = len(stream["ts_us"])
        verdicts = np.full(n, -1, np.int32)
        for i, start in enumerate(range(0, n, cfg.batch_size)):
            sl = slice(start, min(start + cfg.batch_size, n))
            batch = {k: v[sl] for k, v in stream.items()}
            out = self.step(batch)
            verdicts[sl] = out["verdict"]
            if (i + 1) % cfg.control_plane_every == 0:
                self.control_plane()
        return {"verdict": verdicts}

    # -- multi-pipeline driver ----------------------------------------------
    def _route_pipes(self, stream: Dict[str, np.ndarray]
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Packet -> owning pipeline, as contiguous per-pipe segments.

        Returns (order, starts, counts): ``order`` is a stable permutation
        grouping packets by pipe (arrival order preserved within a pipe —
        each pipeline sees its ports' traffic in time order), pipe p's
        packets are ``order[starts[p] : starts[p] + counts[p]]``.
        """
        num_pipes = self.cfg.num_pipes
        h = np.asarray(hash_five_tuple(
            jnp.asarray(stream["src_ip"]), jnp.asarray(stream["dst_ip"]),
            jnp.asarray(stream["src_port"]), jnp.asarray(stream["dst_port"]),
            jnp.asarray(stream["proto"])))
        pipe = pipe_of_hash(h, self.cfg.engine, num_pipes)
        order = np.argsort(pipe, kind="stable")
        counts = np.bincount(pipe, minlength=num_pipes).astype(np.int64)
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        return order, starts, counts

    def _run_trace_pipes(self, stream: Dict[str, np.ndarray]
                         ) -> Dict[str, np.ndarray]:
        """Sharded trace driver: route to pipes, scan all pipes in lockstep
        over the mesh, finish per-pipe tails with the pipe-local step.

        The uniform part runs ``max_p(count_p // B)`` scan steps where every
        pipe consumes a full batch of its own packets per step — one
        ``lax.scan`` over [n_chunks, P, B] with the Data Engine sharded over
        the mesh; pipes whose streams run out early (traffic skew) replay a
        dummy batch with their state frozen (the masked step variant, used
        only for windows that contain such steps).  Each pipe's tail
        (< B packets) is finished through the pipe-local tail step on a
        de-sharded carry.  ``num_pipes=1`` degenerates to exactly the
        single-pipe device driver: one segment, identity permutation, same
        chunking, same control-plane cadence — bit-identical (asserted in
        tests/test_multi_pipe.py).

        Engine-farm mode drives the same loop with the farm step: the
        carry gains the per-engine ingress queues (sharded over the
        ``"engine"`` mesh axis), the scan additionally yields per-engine
        served counts and ingress depths, and tails run through the farm
        tail step (per-engine budget split, engine-tagged results).
        ``num_engines=1`` forced through this path is bit-identical to the
        pipes driver (asserted in tests/test_engine_farm.py).
        """
        cfg = self.cfg
        num_pipes, B, cpe = cfg.num_pipes, cfg.batch_size, \
            cfg.control_plane_every
        use_farm, num_engines = self._use_farm, cfg.num_engines
        n = len(stream["ts_us"])
        arrs = {k: np.asarray(stream[k]) for k in PKT_KEYS}
        if self.oracle is not None and "flow_idx" in stream:
            from repro.data.synthetic_traffic import oracle_payloads
            arrs["payload"] = oracle_payloads(
                self.oracle, stream["flow_idx"], stream["flow_pos"],
                cfg.io.feat_len)
        order, starts, counts = self._route_pipes(stream)
        if use_farm:
            self._ensure_farm_jits()
            scan_plain = self._farm_scan_jit
            scan_masked = self._farm_scan_masked_jit
        else:
            self._ensure_pipe_jits()
            scan_plain = self._pipe_scan_jit
            scan_masked = self._pipe_scan_masked_jit
        # every pipe scans C = max_p(count_p // B) steps so the whole
        # uniform part is ONE sharded lax.scan: pipes whose streams run out
        # early replay a dummy batch with their state frozen (masked step);
        # only the per-pipe tail (< B packets) runs outside the scan
        chunks_p = (counts // B).astype(np.int64)           # [P]
        n_chunks = int(chunks_p.max()) if num_pipes else 0
        t_idx = np.minimum(np.arange(n_chunks)[None, :],
                           np.maximum(chunks_p[:, None] - 1, 0))  # [P, C]
        idx = order[np.minimum(
            starts[:, None, None] + (t_idx * B)[:, :, None]
            + np.arange(B)[None, None, :], n - 1)]          # [P, C, B]
        idx = np.transpose(idx, (1, 0, 2))                  # [C, P, B]
        active = (np.arange(n_chunks)[None, :]
                  < chunks_p[:, None]).T.copy()             # [C, P]
        chunked = {k: jnp.asarray(v[idx]) for k, v in arrs.items()}
        # in-scan control-plane flags: chunk i closes a T_w window when
        # (i+1) % cpe == 0, for every pipe (frozen ones included)
        chunked["_cp"] = jnp.asarray(np.repeat(
            ((np.arange(1, n_chunks + 1) % cpe) == 0)[:, None],
            num_pipes, axis=1))                             # [C, P]
        j_active = jnp.asarray(active)
        carry = (self.pstate, self.pqueues, self.pdl)
        if self._mesh is not None:
            spec = NamedSharding(self._mesh, PartitionSpec("pipe"))
            carry = jax.tree.map(lambda x: jax.device_put(x, spec), carry)
            xspec = NamedSharding(self._mesh, PartitionSpec(None, "pipe"))
            chunked = {k: jax.device_put(v, xspec)
                       for k, v in chunked.items()}
            j_active = jax.device_put(j_active, xspec)
        if use_farm:
            eq = self.eq
            if self._mesh is not None:
                espec = NamedSharding(self._mesh, PartitionSpec("engine"))
                eq = jax.tree.map(lambda x: jax.device_put(x, espec), eq)
            carry = carry + (eq,)
        verd_parts: List[jax.Array] = []                    # [*, P, B] blocks
        stat_rows: List[jax.Array] = []
        served_rows: List[jax.Array] = []
        stat_sum = np.zeros(4, np.int64)
        served_sum = np.zeros(num_engines, np.int64)
        depth_rows: List[np.ndarray] = []                   # [*, E] samples
        # the control plane runs in-scan ("_cp" above): the windowed loop
        # exists only to pick the masked/plain scan variant per window —
        # every output stays a device array until after the loop, so the
        # whole uniform part dispatches with zero host syncs
        for g in range(0, n_chunks, cpe):
            hi = min(g + cpe, n_chunks)
            window = {k: v[g:hi] for k, v in chunked.items()}
            if active[g:hi].all():
                scan = scan_plain
            else:                       # window contains frozen pipe steps
                scan = scan_masked
                window["_active"] = j_active[g:hi]
            if use_farm:
                carry, (vd, st3, served, depth) = scan(carry, window)
                served_rows.append(served)
                depth_rows.append(depth)
                stat_rows.append(st3)
            else:
                carry, (vd, st) = scan(carry, window)
                stat_rows.append(st)
            verd_parts.append(vd)
        if use_farm:
            for st3, served in zip(stat_rows, served_rows):
                served_w = np.asarray(served).astype(np.int64)     # [W, E]
                served_sum += served_w.sum(axis=0)
                s3 = np.asarray(st3).astype(np.int64).sum(axis=0)
                stat_sum += np.asarray([s3[0], served_w.sum(),
                                        s3[1], s3[2]])
            depth_rows = [np.asarray(d).astype(np.int64)
                          for d in depth_rows]
        else:
            for st in stat_rows:
                stat_sum += np.asarray(st).astype(np.int64).sum(axis=0)
        if use_farm:
            self.pstate, self.pqueues, self.pdl, self.eq = carry
        else:
            self.pstate, self.pqueues, self.pdl = carry
        # per-pipe tails (< B packets each) run through the pipe-local tail
        # step; de-shard the carry once first so per-pipe slicing is local
        tails = [p for p in range(num_pipes)
                 if chunks_p[p] * B < counts[p]]
        if tails and self._mesh is not None:
            dev0 = jax.devices()[0]
            self.pstate, self.pqueues, self.pdl = jax.tree.map(
                lambda x: jax.device_put(x, dev0),
                (self.pstate, self.pqueues, self.pdl))
        rem_verds: List[List[np.ndarray]] = [[] for _ in range(num_pipes)]
        n_batches = n_chunks
        for p in tails:
            lo = starts[p] + chunks_p[p] * B
            sel = order[lo:starts[p] + counts[p]]
            batch = {k: jnp.asarray(v[sel]) for k, v in arrs.items()}
            # the stacked window rolls once after ALL tails (below), not
            # per-pipe inside the tail step
            batch["_cp"] = jnp.asarray(False)
            carry_p = jax.tree.map(
                lambda x: x[p], (self.pstate, self.pqueues, self.pdl))
            if use_farm:
                carry_p, (vd, st, assign) = self._farm_tail_jit(carry_p,
                                                                batch)
                served_sum += np.asarray(assign).astype(np.int64)
            else:
                carry_p, (vd, st) = self._pipe_tail_jit(carry_p, batch)
            self.pstate, self.pqueues, self.pdl = jax.tree.map(
                lambda full, part: full.at[p].set(part),
                (self.pstate, self.pqueues, self.pdl), carry_p)
            rem_verds[p].append(np.asarray(vd))
            stat_sum += np.asarray(st).astype(np.int64)
        if tails:
            n_batches += 1
            if use_farm:            # one depth sample per batch round
                depth_rows.append(np.asarray(
                    self.eq["tail"] - self.eq["head"],
                    np.int64).reshape(1, num_engines))
            if n_batches % cpe == 0:
                # T_w rollover after the tail round: jitted dispatch onto
                # the stacked state — still no host round trip
                self.pstate = self._cp_pipes_jit(self.pstate)
        # scatter verdicts back to arrival order (masked scan rows are
        # replayed dummies — only each pipe's first chunks_p[p] rows count)
        verdicts = np.full(n, -1, np.int32)
        scan_vd = (np.concatenate([np.asarray(v) for v in verd_parts],
                                  axis=0) if verd_parts
                   else np.zeros((0, num_pipes, B), np.int32))
        for p in range(num_pipes):
            seq = [scan_vd[:chunks_p[p], p, :].reshape(-1)] + rem_verds[p]
            verdicts[order[starts[p]:starts[p] + counts[p]]] = \
                np.concatenate(seq).astype(np.int32)
        self.stats["packets"] += n
        self.stats["granted"] += int(stat_sum[0])
        self.stats["inferences"] += int(stat_sum[1])
        self.stats["classified_pkts"] += int(stat_sum[2])
        self.stats["tree_pkts"] += int(stat_sum[3])
        self.stats["dropped_q"] = int(np.asarray(
            self.pqueues["dropped"]).sum())
        self.stats["dropped_inflight"] = int(np.asarray(
            self.pdl["dropped"]).sum())
        if use_farm:
            self.stats["served_per_engine"] = [
                a + int(b) for a, b in
                zip(self.stats["served_per_engine"], served_sum)]
            self.stats["dropped_eq"] = int(np.asarray(
                self.eq["dropped"]).sum())
            if depth_rows:
                hist = farm.depth_histogram(
                    np.concatenate(depth_rows, axis=0), num_engines)
                self.stats["engine_q_depth_hist"] = [
                    [a + b for a, b in zip(row, new)] for row, new in
                    zip(self.stats["engine_q_depth_hist"], hist)]
        else:
            self.stats["served_per_engine"][0] += int(stat_sum[1])
            self.stats["engine_q_depth_hist"][0][0] += n_batches
        return {"verdict": verdicts}
