"""FENIX token-generation probability model (paper Eq. 2 + Appendix A).

Control-plane math (floats allowed here, as in the paper — the switch only
ever sees the discretized lookup table built by ``build_lut``).

Variables (Table 5):
  V   token generation rate        = min(F, B/W)    [tokens/s]
  Q   global packet rate           [pkts/s]
  N   number of active flows
  T_i time since flow i last transmitted features   [s]
  C_i packets of flow i backlogged during T_i
  Q_i = C_i / T_i   current flow packet rate

Criterion 1: equal-rate flows get expected transmission period N/V.
Criterion 2: heterogeneous rates get period Q/(Q_i V) (rate-proportional).
Appendix A proves the rate-weighted mean period is exactly N/V.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


def token_rate(fpga_hz: float, link_bw_bytes: float, feat_bytes: int) -> float:
    """Eq. 1: V = min(F, B/W)."""
    return min(fpga_hz, link_bw_bytes / max(feat_bytes, 1))


def probability(t: np.ndarray, c: np.ndarray, n: float, q: float,
                v: float) -> np.ndarray:
    """Eq. 2, vectorized and clipped to [0,1].

    The two linear pieces meet the boundary conditions:
      P=0 while T_i < min(N/V, Q/(Q_i V)) and P=1 past max(...).
    ``QT == NC`` (flow exactly at the mean rate) degenerates to the step at
    N/V (cases 3/4).
    """
    t = np.asarray(t, dtype=np.float64)
    c = np.maximum(np.asarray(c, dtype=np.float64), 1e-12)
    qt = q * t
    nc = n * c
    denom = qt - nc
    # case QT > NC  (flow slower than average): ramp on [N/V, Q/(Q_i V)]
    slow = c * (v * t - n) / np.where(np.abs(denom) < 1e-9, np.inf, denom)
    # case QT < NC  (flow faster than average): ramp on [Q/(Q_i V), N/V]
    fast = t * (v * c - q) / np.where(np.abs(denom) < 1e-9, np.inf, -denom)
    p = np.where(denom > 1e-9, slow, np.where(denom < -1e-9, fast,
                 (t >= n / v).astype(np.float64)))
    return np.clip(p, 0.0, 1.0)


@dataclasses.dataclass(frozen=True)
class LUTConfig:
    """Power-of-two binning so the data plane needs only shifts + clips."""
    t_shift: int = 10          # T bin width = 2^t_shift microseconds
    c_shift: int = 0           # C bin width = 2^c_shift packets
    t_bins: int = 64
    c_bins: int = 32
    prob_bits: int = 16        # probabilities quantized to [0, 2^16)


def build_lut(n: float, q: float, v: float,
              cfg: LUTConfig = LUTConfig()) -> np.ndarray:
    """Discretize Eq. 2 into a [t_bins, c_bins] integer LUT (control plane).

    Entry [i, j] = P(T = (i + 0.5) * 2^t_shift us, C = (j + 0.5) * 2^c_shift)
    scaled to [0, 2^prob_bits).  q, v in pkts/us; n dimensionless.
    """
    ti = (np.arange(cfg.t_bins) + 0.5) * (1 << cfg.t_shift)
    cj = (np.arange(cfg.c_bins) + 0.5) * (1 << cfg.c_shift)
    tt, cc = np.meshgrid(ti, cj, indexing="ij")
    p = probability(tt, cc, n=n, q=q, v=v)
    return np.round(p * ((1 << cfg.prob_bits) - 1)).astype(np.int32)


def probability_jnp(t, c, n, q, v):
    """Eq. 2 as a traceable jnp function (float32) — the on-device mirror
    of :func:`probability`, used by the in-scan control-plane rebuild.

    Bit-compatibility with the float64 numpy path is asserted empirically
    (tests/test_probability.py): every quantized LUT entry the two builds
    produce is identical, because Eq. 2's ramps keep the probabilities far
    from the 16-bit rounding boundaries relative to float32 error.
    """
    f32 = jnp.float32
    t = jnp.asarray(t, f32)
    c = jnp.maximum(jnp.asarray(c, f32), f32(1e-12))
    n = jnp.asarray(n, f32)
    q = jnp.asarray(q, f32)
    v = jnp.asarray(v, f32)
    qt = q * t
    nc = n * c
    denom = qt - nc
    slow = c * (v * t - n) / jnp.where(jnp.abs(denom) < 1e-9,
                                       jnp.inf, denom)
    fast = t * (v * c - q) / jnp.where(jnp.abs(denom) < 1e-9,
                                       jnp.inf, -denom)
    p = jnp.where(denom > 1e-9, slow,
                  jnp.where(denom < -1e-9, fast,
                            (t >= n / v).astype(f32)))
    return jnp.clip(p, 0.0, 1.0)


def build_lut_jnp(flow_cnt, win_pkt_cnt, window_us: int, v: float,
                  cfg: LUTConfig = LUTConfig()):
    """Traceable LUT build straight from the window counters.

    The (N, Q) clamping happens INSIDE the traced function — the host
    oracle and the on-device rebuild both feed raw int32 ``flow_cnt`` /
    ``win_pkt_cnt``, so the two paths share every rounding step and the
    tables they produce are bit-identical (the conformance suite's
    host-vs-device invariant).  ``window_us`` and ``v`` are static config.
    """
    f32 = jnp.float32
    n = jnp.maximum(jnp.asarray(flow_cnt).astype(f32), f32(1.0))
    q = jnp.maximum(jnp.asarray(win_pkt_cnt).astype(f32), f32(1.0)) \
        / f32(max(float(window_us), 1.0))
    ti = (jnp.arange(cfg.t_bins, dtype=f32) + 0.5) * (1 << cfg.t_shift)
    cj = (jnp.arange(cfg.c_bins, dtype=f32) + 0.5) * (1 << cfg.c_shift)
    tt, cc = jnp.meshgrid(ti, cj, indexing="ij")
    p = probability_jnp(tt, cc, n, q, v)
    return jnp.round(p * ((1 << cfg.prob_bits) - 1)).astype(jnp.int32)


def lut_lookup_np(lut: np.ndarray, t_us: np.ndarray, c: np.ndarray,
                  cfg: LUTConfig = LUTConfig()) -> np.ndarray:
    """Reference integer-only lookup (what the switch pipeline does)."""
    ti = np.clip(np.asarray(t_us) >> cfg.t_shift, 0, cfg.t_bins - 1)
    cj = np.clip(np.asarray(c) >> cfg.c_shift, 0, cfg.c_bins - 1)
    return lut[ti, cj]


def expected_period(qi: float, n: float, q: float, v: float) -> float:
    """Appendix A Eq. 6: E_i = (Q_i N + Q) / (2 Q_i V)."""
    return (qi * n + q) / (2.0 * qi * v)


def mean_period_over_flows(rates: np.ndarray, n: float, q: float,
                           v: float) -> float:
    """Appendix A Eq. 7-11: rate-weighted mean == N/V."""
    rates = np.asarray(rates, dtype=np.float64)
    return float(np.sum(rates * np.array(
        [expected_period(r, n, q, v) for r in rates])) / q)
