"""FENIX Data-Engine admission control generalized to LM serving.

The paper's core systems insight — a line-rate front-end must rate-match a
slower inference back-end via probabilistic token-bucket admission with the
fairness property E[interval] = N/V — transfers directly to LM serving:

  flows            -> request streams (tenants/sessions)
  packet rate Q_i  -> request rate of stream i
  FPGA rate F      -> decode-step throughput of the serving mesh
  link B/W         -> ICI/PCIe ingress bytes per request

``ServeGate`` admits decode requests with Eq. 2 probabilities so slow
tenants are not starved by fast ones while the backend stays saturated but
un-overloaded — same math, same LUT, same bucket (§4.2 / Appendix A).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core.probability import LUTConfig, build_lut, lut_lookup_np


@dataclasses.dataclass
class GateConfig:
    backend_rate: float          # requests/s the serving mesh sustains (F)
    ingress_bw_bytes: float = 50e9
    req_bytes: int = 4096        # W: prompt+metadata bytes per admission
    queue_len: int = 128
    window_s: float = 1.0
    lut: LUTConfig = dataclasses.field(default_factory=LUTConfig)

    @property
    def v_per_us(self) -> float:
        return min(self.backend_rate,
                   self.ingress_bw_bytes / self.req_bytes) / 1e6

    @property
    def cost_us(self) -> int:
        return max(1, int(round(1.0 / self.v_per_us)))


class ServeGate:
    """Per-stream probabilistic token-bucket admission (Alg. 1)."""

    def __init__(self, cfg: GateConfig, seed: int = 0,
                 n_streams_est: float = 16.0):
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)
        self.bucket = cfg.queue_len * cfg.cost_us
        self.t_last = 0
        self.backlog_n: Dict[int, int] = {}
        self.backlog_t: Dict[int, int] = {}
        self.win_reqs = 0
        self.win_streams: set = set()
        self.n_est = n_streams_est
        self.lut_cfg = self._adapt_lut_cfg(n_streams_est)
        self.lut = build_lut(n=n_streams_est,
                             q=cfg.backend_rate / 1e6 * 4,
                             v=cfg.v_per_us, cfg=self.lut_cfg)
        self.admitted = 0
        self.denied = 0

    def _adapt_lut_cfg(self, n: float) -> LUTConfig:
        """T bins must span well past the fairness horizon N/V."""
        horizon_us = 4.0 * n / self.cfg.v_per_us
        base = self.cfg.lut
        t_shift = max(int(np.ceil(np.log2(max(horizon_us, 1)
                                          / base.t_bins))), 1)
        return LUTConfig(t_shift=t_shift, c_shift=base.c_shift,
                         t_bins=base.t_bins, c_bins=base.c_bins,
                         prob_bits=base.prob_bits)

    def offer(self, stream_id: int, now_us: int) -> bool:
        cfg = self.cfg
        gap = max(now_us - self.t_last, 0) if self.t_last else 0
        self.t_last = now_us
        self.bucket = min(self.bucket + gap, cfg.queue_len * cfg.cost_us)
        self.win_reqs += 1
        self.win_streams.add(stream_id)
        t_i = now_us - self.backlog_t.get(stream_id, now_us)
        c_i = self.backlog_n.get(stream_id, 0)
        prob = int(lut_lookup_np(self.lut, np.asarray([max(t_i, 0)]),
                                 np.asarray([c_i]), self.lut_cfg)[0])
        rand = int(self.rng.integers(0, 1 << cfg.lut.prob_bits))
        granted = (rand < prob) and self.bucket >= cfg.cost_us
        if granted:
            self.bucket -= cfg.cost_us
            self.backlog_n[stream_id] = 0
            self.backlog_t[stream_id] = now_us
            self.admitted += 1
        else:
            self.backlog_n[stream_id] = c_i + 1
            self.backlog_t.setdefault(stream_id, now_us)
            self.denied += 1
        return granted

    def refresh(self) -> None:
        """Control-plane window rollover: rebuild the LUT from observed
        stream count N and request rate Q."""
        n = max(len(self.win_streams), 1)
        q = max(self.win_reqs, 1) / (self.cfg.window_s * 1e6)
        self.lut_cfg = self._adapt_lut_cfg(n)
        self.lut = build_lut(n=n, q=q, v=self.cfg.v_per_us,
                             cfg=self.lut_cfg)
        self.win_reqs = 0
        self.win_streams = set()
