"""Buffer Manager (§4.3): per-flow feature ring buffers + mirror packets.

The buffer index increments and wraps by compare (the data plane cannot do
modulo — §4.1 "Buffer Index Update").  On a Rate-Limiter grant the ring is
read out in temporal order, the current packet's feature (F9, from packet
metadata) is appended, and the assembled header is attached to a mirrored
packet for the Model Engine.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.data_engine.state import EngineConfig

I32 = jnp.int32


def extract_feature(state: Dict, cfg: EngineConfig, slot, pkt,
                    is_new) -> jax.Array:
    """Per-packet feature vector: (packet length, inter-packet delay)."""
    ipd = jnp.where(is_new, 0, pkt["ts_us"] - state["last_ts"][slot])
    return jnp.stack([pkt["pkt_len"].astype(I32),
                      jnp.maximum(ipd, 0).astype(I32)])


def push(state: Dict, cfg: EngineConfig, slot, feat, ts) -> Dict:
    """Write the feature into the flow's ring; advance buff_idx w/o modulo."""
    s = dict(state)
    idx = state["buff_idx"][slot]
    s["ring"] = state["ring"].at[slot, idx].set(feat)
    nxt = idx + 1
    nxt = jnp.where(nxt == cfg.ring_depth, 0, nxt)   # wrap by compare
    s["buff_idx"] = state["buff_idx"].at[slot].set(nxt)
    s["last_ts"] = state["last_ts"].at[slot].set(ts.astype(I32))
    return s


def assemble(state: Dict, cfg: EngineConfig, slot, cur_feat) -> jax.Array:
    """Mirror-packet payload: ring in temporal order + current feature (F9).

    Reads buff_idx (the NEXT write position == oldest entry) and rolls the
    ring so oldest..newest are contiguous, exactly Figure 7.
    """
    ring = state["ring"][slot]                       # [depth, feat]
    idx = state["buff_idx"][slot]
    order = jnp.mod(idx + jnp.arange(cfg.ring_depth), cfg.ring_depth)
    seq = ring[order]                                # oldest..newest
    return jnp.concatenate([seq, cur_feat[None]], axis=0)  # [depth+1, feat]
