"""Flow Tracker (§4.1): flow table lookup/update + windowed flow counting.

Pure functions over the state dict; the per-packet composition lives in
``engine.py``.  Collision policy: a packet whose slot holds a different hash
evicts the resident flow (initializes the entry) — the paper's "checks
whether the packet belongs to a new flow or is the result of a hash
collision, and then initializes or updates the corresponding flow entry".
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.data_engine.state import EngineConfig, hash_five_tuple

I32 = jnp.int32


def lookup(state: Dict, cfg: EngineConfig, pkt: Dict) -> Tuple:
    """Returns (slot, h, is_new, is_collision)."""
    h = hash_five_tuple(pkt["src_ip"], pkt["dst_ip"], pkt["src_port"],
                        pkt["dst_port"], pkt["proto"])
    slot = (h & jnp.uint32(cfg.n_slots - 1)).astype(I32)
    stored = state["hash"][slot]
    empty = stored == jnp.uint32(0)
    collision = (~empty) & (stored != h)
    is_new = empty | collision
    return slot, h, is_new, collision


def on_packet(state: Dict, cfg: EngineConfig, slot, h, is_new, collision,
              ts) -> Dict:
    """Init-or-update the flow entry; maintain window flow counting."""
    s = dict(state)
    # (re)initialize on new flow / collision eviction
    s["hash"] = state["hash"].at[slot].set(h)
    s["bklog_n"] = state["bklog_n"].at[slot].set(
        jnp.where(is_new, 0, state["bklog_n"][slot] + 1))
    s["bklog_t"] = state["bklog_t"].at[slot].set(
        jnp.where(is_new, ts, state["bklog_t"][slot]))
    s["cls"] = state["cls"].at[slot].set(
        jnp.where(is_new, -1, state["cls"][slot]))
    s["pkt_cnt"] = state["pkt_cnt"].at[slot].set(
        jnp.where(is_new, 1, state["pkt_cnt"][slot] + 1))
    s["buff_idx"] = state["buff_idx"].at[slot].set(
        jnp.where(is_new, 0, state["buff_idx"][slot]))
    # window statistics: count flows whose first packet lands in this T_w
    s["flow_cnt"] = state["flow_cnt"] + is_new.astype(I32)
    s["win_pkt_cnt"] = state["win_pkt_cnt"] + 1
    s["collisions"] = state["collisions"] + collision.astype(I32)
    return s


def window_reset(state: Dict, cfg: EngineConfig, now: jax.Array) -> Dict:
    """Control-plane T_w rollover (§4.1 Flow Counting Mechanism): hash
    registers and the flow counter are reset and recalculated.

    Folded into ``rate_limiter.control_plane_update`` (which anchors the
    new window at the state's own ``t_last``), so the LUT rebuild + reset
    run as one pure jnp function inside the device drivers' scans; callers
    that roll a window without rebuilding the LUT still use this
    directly."""
    s = dict(state)
    s["flow_cnt"] = jnp.asarray(0, I32)
    s["win_pkt_cnt"] = jnp.asarray(0, I32)
    s["win_start"] = now.astype(I32)
    return s


def window_reset_pipes(state: Dict, cfg: EngineConfig) -> Dict:
    """T_w rollover for a stacked [num_pipes, ...] state: each pipe's flow
    counter and packet counter restart, anchored at that pipe's own clock
    (``t_last`` differs across pipes — each pipeline sees only its ports)."""
    s = dict(state)
    s["flow_cnt"] = jnp.zeros_like(state["flow_cnt"])
    s["win_pkt_cnt"] = jnp.zeros_like(state["win_pkt_cnt"])
    s["win_start"] = state["t_last"].astype(I32)
    return s


def apply_inference_result(state: Dict, slot, cls, h) -> Dict:
    """Model Engine verdict returns to the switch (§5.1): write cls if the
    slot still belongs to the same flow (hash check handles eviction races).
    """
    s = dict(state)
    still_owner = state["hash"][slot] == h
    s["cls"] = state["cls"].at[slot].set(
        jnp.where(still_owner, cls, state["cls"][slot]))
    return s
