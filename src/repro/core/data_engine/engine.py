"""The fused Data Engine (§4): per-packet switch pipeline as a lax.scan.

``process_batch`` preserves the exact per-packet sequential semantics of the
switch (shared token bucket, ring ordering) by scanning over packets; the
stateless stages (hashing, LUT lookup, feature assembly) vectorize inside
each scan step.  ``process_batch_fast`` is the vectorized throughput mode
used by the Tbps-scale simulator: identical flow/ring/probability semantics,
token-bucket admission approximated by a prefix-sum credit check (documented
deviation; validated against the scan mode in tests).

Both are *per-shard pure functions*: every table, bucket, and PRNG they
touch lives in the state dict they are handed.  The multi-pipeline data
plane exploits this directly — ``shard_map`` (or ``process_pipes_fast``'s
vmap) runs ``process_batch_fast`` once per pipe against that pipe's slice
of the stacked state, with the *local* ``EngineConfig``
(``local_engine_config``: 1/P of the slot space, 1/P of the token rate) and
zero cross-pipe communication.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.data_engine import buffer_manager as bm
from repro.core.data_engine import flow_tracker as ft
from repro.core.data_engine import rate_limiter as rl
from repro.core.data_engine.state import EngineConfig

I32 = jnp.int32


def _packet_step(state: Dict, pkt: Dict, cfg: EngineConfig,
                 tree: Optional[Dict] = None, tree_depth: int = 4):
    """One packet through Flow Tracker -> Rate Limiter -> Buffer Manager."""
    ts = pkt["ts_us"].astype(I32)
    slot, h, is_new, collision = ft.lookup(state, cfg, pkt)
    state = ft.on_packet(state, cfg, slot, h, is_new, collision, ts)
    feat = bm.extract_feature(state, cfg, slot, pkt, is_new)
    # rate limiter decides whether this flow ships features now
    state, granted = rl.step(state, cfg, slot, ts)
    # mirror packet payload (F1..F8 + current F9), valid when granted
    payload = bm.assemble(state, cfg, slot, feat)
    state = bm.push(state, cfg, slot, feat, ts)
    # preliminary per-packet verdict (§4.1): stored class else switch tree
    stored_cls = state["cls"][slot]
    if tree is not None:
        from repro.core.data_engine.decision_tree import predict
        pre = predict(tree, feat, tree_depth)
    else:
        pre = jnp.asarray(-1, I32)
    verdict = jnp.where(stored_cls >= 0, stored_cls, pre)
    out = {"granted": granted, "slot": slot, "hash": h,
           "payload": payload, "verdict": verdict, "is_new": is_new}
    return state, out


@functools.partial(jax.jit, static_argnames=("cfg", "tree_depth"))
def process_batch(state: Dict, packets: Dict, cfg: EngineConfig,
                  tree: Optional[Dict] = None, tree_depth: int = 4
                  ) -> Tuple[Dict, Dict]:
    """Scan a packet batch through the pipeline (exact semantics).

    packets: dict of [n] arrays. Returns (state', outputs of shape [n, ...]).
    """

    def step(st, pkt):
        return _packet_step(st, pkt, cfg, tree=tree, tree_depth=tree_depth)

    return jax.lax.scan(step, state, packets)


@functools.partial(jax.jit, static_argnames=("cfg",))
def process_batch_fast(state: Dict, packets: Dict, cfg: EngineConfig
                       ) -> Tuple[Dict, Dict]:
    """Vectorized admission (simulator fast path).

    Probability gating is exact; the shared token bucket is approximated by
    granting selected packets while their cumulative cost fits the credit
    available at batch start + refill up to each arrival.
    """
    from repro.core.data_engine.state import hash_five_tuple

    n = packets["ts_us"].shape[0]
    ts = packets["ts_us"].astype(I32)
    h = hash_five_tuple(packets["src_ip"], packets["dst_ip"],
                        packets["src_port"], packets["dst_port"],
                        packets["proto"])
    slot = (h & jnp.uint32(cfg.n_slots - 1)).astype(I32)
    stored = state["hash"][slot]
    # first occurrence of each slot in this batch determines new/collision
    first_in_batch = _first_occurrence(slot, cfg.n_slots)
    is_new = first_in_batch & ((stored == 0) | (stored != h))
    # probability lookup against batch-start backlog (approximation)
    run = (_running_count_dense(slot, n) if cfg.dense_backlog
           else _running_count(slot, n))
    t_i = jnp.maximum(ts - state["bklog_t"][slot], 0)
    c_i = jnp.maximum(state["bklog_n"][slot], 0) + run
    key, sub = jax.random.split(state["rng_key"])
    rand = jax.random.randint(sub, (n,), 0, 1 << cfg.lut.prob_bits, I32)
    # fused admission: LUT lookup + threshold + token bucket in ONE call
    # (rl.admit_batch -> fused_admission).  Bucket semantics: spend_i <=
    # burst credit (capped at batch start) + refill_i.  The cap limits
    # *idle accumulation*, not throughput: refill earned during the batch
    # is spendable immediately (matches the scan semantics whenever packet
    # timestamps are spread out; see test_data_engine).
    granted, bucket_new = rl.admit_batch(state, cfg, t_i, c_i, ts, rand)
    state = dict(state)
    state["rng_key"] = key
    state["bucket"] = bucket_new
    state["t_last"] = ts[-1]
    state["granted"] = state["granted"] + granted.sum().astype(I32)
    # features + mirror payloads from the PRE-update ring (F1..F8 then F9);
    # ipd is 0 for flows new to the table (no previous timestamp)
    known = (stored != 0) & (stored == h)
    feat = jnp.stack(
        [packets["pkt_len"].astype(I32),
         jnp.where(known, jnp.maximum(ts - state["last_ts"][slot], 0), 0)],
        axis=-1)
    idx = state["buff_idx"][slot]
    order = jnp.mod(idx[:, None] + jnp.arange(cfg.ring_depth)[None],
                    cfg.ring_depth)
    seq = jnp.take_along_axis(state["ring"][slot], order[..., None], axis=1)
    payload = jnp.concatenate([seq, feat[:, None]], axis=1)
    # flow table bulk update (last write per slot wins)
    state["hash"] = state["hash"].at[slot].set(h)
    state["ring"] = state["ring"].at[slot, idx].set(feat)
    nxt = jnp.where(idx + 1 == cfg.ring_depth, 0, idx + 1)
    state["buff_idx"] = state["buff_idx"].at[slot].set(nxt)
    state["last_ts"] = state["last_ts"].at[slot].set(ts)
    state["bklog_n"] = state["bklog_n"].at[slot].add(1)
    state["bklog_n"] = state["bklog_n"].at[slot].set(
        jnp.where(granted, 0, state["bklog_n"][slot]))
    state["bklog_t"] = state["bklog_t"].at[slot].set(
        jnp.where(granted, ts, state["bklog_t"][slot]))
    state["flow_cnt"] = state["flow_cnt"] + is_new.sum().astype(I32)
    state["win_pkt_cnt"] = state["win_pkt_cnt"] + n
    out = {"granted": granted, "slot": slot, "hash": h, "payload": payload,
           "verdict": jnp.where(state["cls"][slot] >= 0,
                                state["cls"][slot], -1),
           "is_new": is_new}
    return state, out


@functools.partial(jax.jit, static_argnames=("local_cfg",))
def process_pipes_fast(states: Dict, packets: Dict,
                       local_cfg: EngineConfig) -> Tuple[Dict, Dict]:
    """Vectorized admission across pipes: states/packets carry a leading
    [num_pipes] dim, each pipe running ``process_batch_fast`` on its own
    table, bucket, and PRNG stream.  The mesh-sharded driver in ``fenix.py``
    wraps the same per-pipe function in ``shard_map``; this vmap form is the
    1-device fallback and the unit-testable reference for it.
    """
    return jax.vmap(lambda st, pk: process_batch_fast(st, pk, local_cfg)
                    )(states, packets)


def _first_occurrence(slot: jax.Array, n_slots: int) -> jax.Array:
    """Mask of packets that are the first in batch to touch their slot."""
    n = slot.shape[0]
    first_idx = jnp.full((n_slots,), n, jnp.int32).at[slot].min(
        jnp.arange(n, dtype=jnp.int32))
    return first_idx[slot] == jnp.arange(n)


def _running_count(slot: jax.Array, n: int) -> jax.Array:
    """#earlier packets in this batch with the same slot (backlog adjust).

    O(n log n) sort/segment formulation: stable-sort packets by slot (ties
    keep batch order), then each packet's rank within its equal-slot run —
    position minus the running maximum of run-start positions — IS the
    count of earlier same-slot packets.  No n x n intermediate, so batch
    sizes of 4096-8192 stay cache-resident.
    """
    order = jnp.argsort(slot, stable=True)
    s = slot[order]
    idx = jnp.arange(n, dtype=I32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), s[1:] != s[:-1]])
    seg_first = jax.lax.cummax(jnp.where(is_start, idx, 0))
    run_sorted = idx - seg_first
    return jnp.zeros((n,), I32).at[order].set(run_sorted)


def _running_count_dense(slot: jax.Array, n: int) -> jax.Array:
    """O(n^2) reference for ``_running_count`` (tests + throughput bench)."""
    eq = slot[None, :] == slot[:, None]
    tri = jnp.tril(jnp.ones((n, n), bool), k=-1)
    return jnp.sum(eq & tri, axis=1).astype(I32)
