"""Rate Limiter (§4.2): probabilistic token bucket, Algorithm 1.

Integer-only data-plane math: the probability comes from the control-plane
LUT (power-of-two binning => shift + clip), randomness is a 16-bit draw, the
bucket holds microseconds of credit (cost = 1/V us per grant, cap = queue
length * cost so bursts are absorbed without overflowing the queue).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.data_engine.state import EngineConfig

I32 = jnp.int32


def step(state: Dict, cfg: EngineConfig, slot, ts) -> Tuple[Dict, jax.Array]:
    """Algorithm 1 for one packet. Returns (state', granted?)."""
    s = dict(state)
    # lines 1-5: refill by elapsed gap
    first = state["t_last"] == 0
    gap = jnp.where(first, 0, ts - state["t_last"])
    s["t_last"] = ts.astype(I32)
    bucket = jnp.minimum(state["bucket"] + gap, cfg.bucket_cap_us)
    # line 6: rand + LUT probability on (T_i, C_i)
    key, sub = jax.random.split(state["rng_key"])
    s["rng_key"] = key
    rand = jax.random.randint(sub, (), 0, 1 << cfg.lut.prob_bits, I32)
    t_i = jnp.maximum(ts - state["bklog_t"][slot], 0)
    c_i = jnp.maximum(state["bklog_n"][slot], 0)
    ti_bin = jnp.clip(t_i >> cfg.lut.t_shift, 0, cfg.lut.t_bins - 1)
    ci_bin = jnp.clip(c_i >> cfg.lut.c_shift, 0, cfg.lut.c_bins - 1)
    prob = state["lut"][ti_bin, ci_bin]
    selected = rand < prob
    # lines 8-12: consume if selected and enough tokens
    has_tokens = bucket >= cfg.cost_us
    granted = selected & has_tokens
    s["bucket"] = jnp.where(granted, bucket - cfg.cost_us, bucket).astype(I32)
    # telemetry + per-flow backlog reset on grant
    s["granted"] = state["granted"] + granted.astype(I32)
    s["denied_prob"] = state["denied_prob"] + (~selected).astype(I32)
    s["denied_tokens"] = state["denied_tokens"] \
        + (selected & ~has_tokens).astype(I32)
    s["bklog_n"] = s["bklog_n"].at[slot].set(
        jnp.where(granted, 0, s["bklog_n"][slot]))
    s["bklog_t"] = s["bklog_t"].at[slot].set(
        jnp.where(granted, ts, s["bklog_t"][slot]))
    return s, granted


def control_plane_update(state: Dict, cfg: EngineConfig) -> Dict:
    """Rebuild the LUT from the observed window statistics (N, Q).

    This is the paper's 300-line control-plane Python component: it reads
    Flow_cnt / Pkt_cnt from the switch each T_w and pushes a fresh table.
    """
    import numpy as np

    from repro.core.probability import build_lut

    n = max(float(state["flow_cnt"]), 1.0)
    q = max(float(state["win_pkt_cnt"]), 1.0) / max(float(cfg.window_us), 1.0)
    lut = build_lut(n=n, q=q, v=cfg.token_rate_per_us, cfg=cfg.lut)
    s = dict(state)
    s["lut"] = jnp.asarray(lut, I32)
    return s
