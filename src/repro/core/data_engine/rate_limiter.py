"""Rate Limiter (§4.2): probabilistic token bucket, Algorithm 1.

Integer-only data-plane math: the probability comes from the control-plane
LUT (power-of-two binning => shift + clip), randomness is a 16-bit draw, the
bucket holds microseconds of credit (cost = 1/V us per grant, cap = queue
length * cost so bursts are absorbed without overflowing the queue).

``step`` and the fast-path admission in ``engine.py`` are per-shard pure
functions: all bucket/backlog state they touch lives in the state dict they
are handed, so under the multi-pipeline layout each pipe runs them against
its *local* bucket (refilled at ``rate / num_pipes`` via
``local_engine_config``) with no cross-pipe coupling.  The control plane
rebuilds one LUT per pipe from that pipe's own (N, Q) window statistics.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.data_engine.state import EngineConfig

I32 = jnp.int32


def step(state: Dict, cfg: EngineConfig, slot, ts) -> Tuple[Dict, jax.Array]:
    """Algorithm 1 for one packet. Returns (state', granted?)."""
    s = dict(state)
    # lines 1-5: refill by elapsed gap
    first = state["t_last"] == 0
    gap = jnp.where(first, 0, ts - state["t_last"])
    s["t_last"] = ts.astype(I32)
    bucket = jnp.minimum(state["bucket"] + gap, cfg.bucket_cap_us)
    # line 6: rand + LUT probability on (T_i, C_i) — same shift/clip/gather
    # as the batch paths (lut_prob is the single lookup site)
    from repro.kernels.rate_gate.ref import lut_prob

    key, sub = jax.random.split(state["rng_key"])
    s["rng_key"] = key
    rand = jax.random.randint(sub, (), 0, 1 << cfg.lut.prob_bits, I32)
    t_i = jnp.maximum(ts - state["bklog_t"][slot], 0)
    c_i = jnp.maximum(state["bklog_n"][slot], 0)
    selected = rand < lut_prob(state["lut"], t_i, c_i, cfg.lut.t_shift,
                               cfg.lut.c_shift)
    # lines 8-12: consume if selected and enough tokens
    has_tokens = bucket >= cfg.cost_us
    granted = selected & has_tokens
    s["bucket"] = jnp.where(granted, bucket - cfg.cost_us, bucket).astype(I32)
    # telemetry + per-flow backlog reset on grant
    s["granted"] = state["granted"] + granted.astype(I32)
    s["denied_prob"] = state["denied_prob"] + (~selected).astype(I32)
    s["denied_tokens"] = state["denied_tokens"] \
        + (selected & ~has_tokens).astype(I32)
    s["bklog_n"] = s["bklog_n"].at[slot].set(
        jnp.where(granted, 0, s["bklog_n"][slot]))
    s["bklog_t"] = s["bklog_t"].at[slot].set(
        jnp.where(granted, ts, s["bklog_t"][slot]))
    return s, granted


def admit_batch(state: Dict, cfg: EngineConfig, t_i: jax.Array,
                c_i: jax.Array, ts: jax.Array, rand16: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Vectorized Algorithm 1 for one packet batch: ONE fused call.

    LUT lookup, threshold draw, and the prefix-sum token-bucket credit
    check run as a single op against the state's LUT and bucket registers
    — the jnp oracle when ``cfg.gate_backend == "ref"``, the fused Pallas
    kernel otherwise (bit-identical in interpret mode; the TPU backend
    swaps the host-supplied draws for the on-core PRNG).  Returns
    (granted [n] bool, bucket_new scalar); the caller owns the rest of
    the state update (t_last, counters) exactly as before.
    """
    from repro.kernels.rate_gate.ops import fused_admission

    return fused_admission(
        t_i, c_i, ts, state["lut"], state["bucket"], state["t_last"],
        rand16=rand16, cost_us=cfg.cost_us,
        bucket_cap_us=cfg.bucket_cap_us, t_shift=cfg.lut.t_shift,
        c_shift=cfg.lut.c_shift, prob_bits=cfg.lut.prob_bits,
        backend=cfg.gate_backend)


def control_plane_update(state: Dict, cfg: EngineConfig) -> Dict:
    """Rebuild the LUT from the observed window statistics (N, Q).

    This is the paper's 300-line control-plane Python component: it reads
    Flow_cnt / Pkt_cnt from the switch each T_w and pushes a fresh table.
    """
    s = dict(state)
    s["lut"] = jnp.asarray(_lut_from_window(state["flow_cnt"],
                                            state["win_pkt_cnt"], cfg), I32)
    return s


def _lut_from_window(flow_cnt, win_pkt_cnt, cfg: EngineConfig):
    """One window's (N, Q) clamping + LUT build — the single formula site
    shared by the single-pipe and per-pipe control planes."""
    from repro.core.probability import build_lut

    n = max(float(flow_cnt), 1.0)
    q = max(float(win_pkt_cnt), 1.0) / max(float(cfg.window_us), 1.0)
    return build_lut(n=n, q=q, v=cfg.token_rate_per_us, cfg=cfg.lut)


def control_plane_update_pipes(state: Dict, local_cfg: EngineConfig,
                               num_pipes: int) -> Dict:
    """Per-pipe LUT rebuild over a stacked [num_pipes, ...] state.

    Each pipe gets its own table from its own window statistics and its own
    rate share (``local_cfg.token_rate_per_us`` is already the per-pipe V);
    pipe 0 of a one-pipe layout reproduces ``control_plane_update`` exactly.
    This is the single host sync per control-plane window — one
    device->host read of the [num_pipes] counters, one LUT push back.
    """
    import numpy as np

    flow_cnt = np.asarray(state["flow_cnt"], np.int64)
    win_pkt = np.asarray(state["win_pkt_cnt"], np.int64)
    luts = [_lut_from_window(flow_cnt[p], win_pkt[p], local_cfg)
            for p in range(num_pipes)]
    s = dict(state)
    s["lut"] = jnp.asarray(np.stack(luts), I32)
    return s
