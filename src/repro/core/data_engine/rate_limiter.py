"""Rate Limiter (§4.2): probabilistic token bucket, Algorithm 1.

Integer-only data-plane math: the probability comes from the control-plane
LUT (power-of-two binning => shift + clip), randomness is a 16-bit draw, the
bucket holds microseconds of credit (cost = 1/V us per grant, cap = queue
length * cost so bursts are absorbed without overflowing the queue).

``step`` and the fast-path admission in ``engine.py`` are per-shard pure
functions: all bucket/backlog state they touch lives in the state dict they
are handed, so under the multi-pipeline layout each pipe runs them against
its *local* bucket (refilled at ``rate / num_pipes`` via
``local_engine_config``) with no cross-pipe coupling.  The control plane
rebuilds one LUT per pipe from that pipe's own (N, Q) window statistics.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.data_engine.state import EngineConfig

I32 = jnp.int32


def step(state: Dict, cfg: EngineConfig, slot, ts) -> Tuple[Dict, jax.Array]:
    """Algorithm 1 for one packet. Returns (state', granted?)."""
    s = dict(state)
    # lines 1-5: refill by elapsed gap
    first = state["t_last"] == 0
    gap = jnp.where(first, 0, ts - state["t_last"])
    s["t_last"] = ts.astype(I32)
    bucket = jnp.minimum(state["bucket"] + gap, cfg.bucket_cap_us)
    # line 6: rand + LUT probability on (T_i, C_i) — same shift/clip/gather
    # as the batch paths (lut_prob is the single lookup site)
    from repro.kernels.rate_gate.ref import lut_prob

    key, sub = jax.random.split(state["rng_key"])
    s["rng_key"] = key
    rand = jax.random.randint(sub, (), 0, 1 << cfg.lut.prob_bits, I32)
    t_i = jnp.maximum(ts - state["bklog_t"][slot], 0)
    c_i = jnp.maximum(state["bklog_n"][slot], 0)
    selected = rand < lut_prob(state["lut"], t_i, c_i, cfg.lut.t_shift,
                               cfg.lut.c_shift)
    # lines 8-12: consume if selected and enough tokens
    has_tokens = bucket >= cfg.cost_us
    granted = selected & has_tokens
    s["bucket"] = jnp.where(granted, bucket - cfg.cost_us, bucket).astype(I32)
    # telemetry + per-flow backlog reset on grant
    s["granted"] = state["granted"] + granted.astype(I32)
    s["denied_prob"] = state["denied_prob"] + (~selected).astype(I32)
    s["denied_tokens"] = state["denied_tokens"] \
        + (selected & ~has_tokens).astype(I32)
    s["bklog_n"] = s["bklog_n"].at[slot].set(
        jnp.where(granted, 0, s["bklog_n"][slot]))
    s["bklog_t"] = s["bklog_t"].at[slot].set(
        jnp.where(granted, ts, s["bklog_t"][slot]))
    return s, granted


def admit_batch(state: Dict, cfg: EngineConfig, t_i: jax.Array,
                c_i: jax.Array, ts: jax.Array, rand16: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Vectorized Algorithm 1 for one packet batch: ONE fused call.

    LUT lookup, threshold draw, and the prefix-sum token-bucket credit
    check run as a single op against the state's LUT and bucket registers
    — the jnp oracle when ``cfg.gate_backend == "ref"``, the fused Pallas
    kernel otherwise (bit-identical in interpret mode; the TPU backend
    swaps the host-supplied draws for the on-core PRNG).  Returns
    (granted [n] bool, bucket_new scalar); the caller owns the rest of
    the state update (t_last, counters) exactly as before.
    """
    from repro.kernels.rate_gate.ops import fused_admission

    return fused_admission(
        t_i, c_i, ts, state["lut"], state["bucket"], state["t_last"],
        rand16=rand16, cost_us=cfg.cost_us,
        bucket_cap_us=cfg.bucket_cap_us, t_shift=cfg.lut.t_shift,
        c_shift=cfg.lut.c_shift, prob_bits=cfg.lut.prob_bits,
        backend=cfg.gate_backend)


def control_plane_update(state: Dict, cfg: EngineConfig) -> Dict:
    """T_w rollover: rebuild the LUT from the observed window statistics
    (N, Q) and reset the window counters — as one pure jnp function.

    This is the paper's 300-line control-plane Python component, but
    expressed entirely over array state so the device drivers can invoke
    it INSIDE the jitted ``lax.scan`` at window boundaries (zero host
    round-trips per window).  The host reference loop calls the same
    function eagerly between batches — both paths share every rounding
    step of :func:`repro.core.probability.build_lut_jnp`, which is what
    keeps the rebuilt tables bit-identical across drivers (the
    conformance suite's invariant).  ``flow_tracker.window_reset`` is
    folded in: the new window anchors at the state's own clock
    (``t_last``), no host-supplied "now" needed.
    """
    from repro.core.data_engine import flow_tracker as ft
    from repro.core.probability import build_lut_jnp

    s = dict(state)
    s["lut"] = build_lut_jnp(state["flow_cnt"], state["win_pkt_cnt"],
                             window_us=cfg.window_us,
                             v=cfg.token_rate_per_us, cfg=cfg.lut)
    return ft.window_reset(s, cfg, state["t_last"])


def control_plane_update_pipes(state: Dict, local_cfg: EngineConfig,
                               num_pipes: int = 0) -> Dict:
    """Per-pipe LUT rebuild + window reset over a stacked
    [num_pipes, ...] state, pure jnp (a vmap of
    :func:`control_plane_update`).

    Each pipe gets its own table from its own window statistics and its
    own rate share (``local_cfg.token_rate_per_us`` is already the
    per-pipe V), anchored at that pipe's own clock; pipe 0 of a one-pipe
    layout reproduces ``control_plane_update`` exactly.  Runs unchanged
    inside the sharded scans (per-pipe pure function, no cross-pipe
    coupling) or eagerly from the host oracle.  ``num_pipes`` is kept for
    signature compatibility; the stacked leading dim is authoritative.
    """
    return jax.vmap(lambda st: control_plane_update(st, local_cfg))(state)
