"""Data Engine state: the switch's SRAM tables as JAX arrays.

Mirrors §4.1 Figure 3: a Flow Info Table keyed by truncated 5-tuple hash with
fields {hash, bklog_n, bklog_t, class, buff_idx, pkt_cnt}; per-flow feature
ring buffers (§4.3); token bucket + windowed global statistics (§4.2).

All fields are integers — the data plane performs no float math, matching
PISA's instruction set.  Timestamps are int32 microseconds.

Multi-pipeline layout: a Tofino runs 2-4 independent ingress pipelines, each
with its own register file and its own share of line rate.  ``num_pipes``
partitions the *global* slot space by range: a flow's global slot
``s = hash & (n_slots - 1)`` splits into high bits (the owning pipe,
``pipe_of_hash``) and low bits (the slot inside that pipe's table,
``local_engine_config`` shrinks ``n_slots_log2`` accordingly).  Two flows
collide in the P-pipe layout iff they collide in the single-pipe table, so
there is no cross-pipe flow aliasing and the collision structure is
preserved exactly.  Each pipe's token bucket runs at ``rate / num_pipes``
(its share of the one FPGA Model Engine), and ``init_pipes_state`` stacks
per-pipe copies of the single-pipe state along a leading "pipe" axis —
the layout ``shard_map`` shards over the mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.probability import LUTConfig, build_lut, token_rate

I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots_log2: int = 12          # flow table size = 2^k
    ring_depth: int = 8             # F1..F8 (§4.3); current pkt is F9
    feat_dim: int = 2               # (pkt_len, inter-packet delay)
    # token bucket (§4.2): cost per feature-vector grant, in microseconds
    fpga_hz: float = 75e6           # model engine service rate (Fig. 6)
    link_bw_bytes: float = 12.5e9   # 100 Gbps switch<->FPGA channel
    feat_bytes: int = 64            # W: mirrored packet payload
    queue_len: int = 64             # bucket cap <= queue length (§4.2)
    window_us: int = 1_000_000      # T_w statistics window
    lut: LUTConfig = dataclasses.field(default_factory=LUTConfig)
    # probability-gate backend for the vectorized fast path — the FUSED
    # admission op (LUT lookup + threshold + token bucket, one call per
    # chunk; see kernels/rate_gate/ops.fused_admission):
    #   "ref"        pure-jnp oracle (bit-exact with the scan mode)
    #   "pallas"     fused Pallas kernel, interpret mode (CPU fallback,
    #                bit-identical to "ref")
    #   "pallas_tpu" compiled fused Pallas kernel with the on-core PRNG
    gate_backend: str = "ref"
    # use the O(n^2) dense backlog count instead of the sort/segment path
    # (reference implementation, kept for tests and the throughput bench)
    dense_backlog: bool = False

    @property
    def n_slots(self) -> int:
        return 1 << self.n_slots_log2

    @property
    def token_rate_per_us(self) -> float:
        return token_rate(self.fpga_hz, self.link_bw_bytes,
                          self.feat_bytes) / 1e6

    @property
    def cost_us(self) -> int:
        """Token cost per grant = 1/V in us (integer, >=1)."""
        return max(1, int(round(1.0 / self.token_rate_per_us)))

    @property
    def bucket_cap_us(self) -> int:
        return self.queue_len * self.cost_us


def init_state(cfg: EngineConfig, n_est: float = 1000.0,
               q_est_pps: float = 1e6) -> Dict[str, jax.Array]:
    """Fresh switch state + a control-plane LUT for (n_est, q_est)."""
    n = cfg.n_slots
    lut = build_lut(n=n_est, q=q_est_pps / 1e6,
                    v=cfg.token_rate_per_us, cfg=cfg.lut)
    return {
        # Flow Info Table (§4.1)
        "hash": jnp.zeros((n,), jnp.uint32),
        "bklog_n": jnp.zeros((n,), I32),
        "bklog_t": jnp.zeros((n,), I32),
        "cls": jnp.full((n,), -1, I32),
        "buff_idx": jnp.zeros((n,), I32),
        "pkt_cnt": jnp.zeros((n,), I32),
        "last_ts": jnp.zeros((n,), I32),
        # Buffer Manager rings (§4.3)
        "ring": jnp.zeros((n, cfg.ring_depth, cfg.feat_dim), I32),
        # Rate Limiter (§4.2)
        "bucket": jnp.asarray(cfg.bucket_cap_us, I32),
        "t_last": jnp.asarray(0, I32),
        "lut": jnp.asarray(lut, I32),
        # windowed statistics (control plane resets each T_w)
        "flow_cnt": jnp.asarray(0, I32),
        "win_pkt_cnt": jnp.asarray(0, I32),
        "win_start": jnp.asarray(0, I32),
        # PRNG for probabilistic selection
        "rng_key": jax.random.PRNGKey(0),
        # telemetry
        "granted": jnp.asarray(0, I32),
        "denied_prob": jnp.asarray(0, I32),
        "denied_tokens": jnp.asarray(0, I32),
        "collisions": jnp.asarray(0, I32),
    }


def farm_engine_config(cfg: EngineConfig, num_engines: int) -> EngineConfig:
    """The switch-side view of an ``num_engines``-strong Model-Engine farm.

    ``cfg`` describes ONE FPGA engine; a farm of E engines multiplies the
    aggregate service rate and the switch<->FPGA channel count by E, so the
    switch's token bucket (admission) refills E times faster — the farm's
    pooled capacity.  Per-engine service budgets in the farm step still use
    the *single-engine* rate; their sum is this config's rate, so admission
    and service stay balanced.  ``num_engines=1`` returns a config equal to
    ``cfg`` (the single-engine paths are unchanged).
    """
    if num_engines < 1:
        raise ValueError(f"num_engines must be >= 1, got {num_engines}")
    return dataclasses.replace(
        cfg, fpga_hz=cfg.fpga_hz * num_engines,
        link_bw_bytes=cfg.link_bw_bytes * num_engines)


def local_engine_config(cfg: EngineConfig, num_pipes: int) -> EngineConfig:
    """The per-pipeline view of a global ``EngineConfig``.

    Slot-range partitioning: each pipe owns ``n_slots / num_pipes`` table
    entries, addressed by the low bits of the global slot (so the per-pipe
    ``process_batch_fast`` computes exactly the right local slot from the
    hash).  The Model-Engine service rate and the switch<->FPGA channel are
    shared resources, so each pipe's token bucket refills at ``1/num_pipes``
    of the global rate — the per-pipeline line-rate share.  ``num_pipes=1``
    returns a config equal to ``cfg`` (the single-pipe path is unchanged).
    """
    if num_pipes < 1 or num_pipes & (num_pipes - 1):
        raise ValueError(f"num_pipes must be a power of two, got {num_pipes}")
    p_log2 = num_pipes.bit_length() - 1
    if p_log2 > cfg.n_slots_log2:
        raise ValueError(f"num_pipes={num_pipes} exceeds n_slots="
                         f"{cfg.n_slots}")
    return dataclasses.replace(
        cfg, n_slots_log2=cfg.n_slots_log2 - p_log2,
        fpga_hz=cfg.fpga_hz / num_pipes,
        link_bw_bytes=cfg.link_bw_bytes / num_pipes)


def pipe_of_hash(h, cfg: EngineConfig, num_pipes: int):
    """Owning pipeline of a flow: the high bits of its global table slot.

    Works on np or jnp uint32 arrays; the complementary low bits are the
    slot the pipe-local engine derives itself (``h & (local_n_slots - 1)``).
    """
    p_log2 = num_pipes.bit_length() - 1
    gslot = h & np.uint32(cfg.n_slots - 1)
    return (gslot >> np.uint32(cfg.n_slots_log2 - p_log2)).astype(np.int32) \
        if isinstance(gslot, np.ndarray) else \
        (gslot >> jnp.uint32(cfg.n_slots_log2 - p_log2)).astype(I32)


def init_pipes_state(cfg: EngineConfig, num_pipes: int,
                     n_est: float = 1000.0, q_est_pps: float = 1e6
                     ) -> Dict[str, jax.Array]:
    """Stacked per-pipe state: every field gains a leading [num_pipes] dim.

    Each pipe is an independent ``init_state`` of the *local* config (its
    slot range, its rate share, its share of the flow/packet estimates);
    pipe p seeds its own PRNG stream with ``PRNGKey(p)`` so pipe 0 of a
    one-pipe layout is bit-identical to the single-pipe state.
    """
    lcfg = local_engine_config(cfg, num_pipes)
    one = init_state(lcfg, n_est=n_est / num_pipes,
                     q_est_pps=q_est_pps / num_pipes)
    stacked = {k: jnp.stack([one[k]] * num_pipes) for k in one}
    stacked["rng_key"] = jnp.stack(
        [jax.random.PRNGKey(p) for p in range(num_pipes)])
    return stacked


def hash_five_tuple(src_ip, dst_ip, src_port, dst_port, proto):
    """32-bit integer mix of the 5-tuple (stand-in for the switch CRC)."""
    h = src_ip.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
    h = h ^ (dst_ip.astype(jnp.uint32) * jnp.uint32(0x85EBCA77))
    h = h ^ (src_port.astype(jnp.uint32) * jnp.uint32(0xC2B2AE3D))
    h = h ^ (dst_port.astype(jnp.uint32) * jnp.uint32(0x27D4EB2F))
    h = h ^ (proto.astype(jnp.uint32) * jnp.uint32(0x165667B1))
    h = h ^ (h >> jnp.uint32(15))
    h = h * jnp.uint32(0x2545F491)
    h = h ^ (h >> jnp.uint32(13))
    # hash value 0 is reserved for "empty slot"
    return jnp.maximum(h, jnp.uint32(1))


def make_packets(rng: np.random.Generator, n: int) -> Dict[str, np.ndarray]:
    """Random packet batch skeleton (tests)."""
    return {
        "src_ip": rng.integers(0, 2**31, n, dtype=np.int64).astype(np.uint32),
        "dst_ip": rng.integers(0, 2**31, n, dtype=np.int64).astype(np.uint32),
        "src_port": rng.integers(0, 65536, n).astype(np.uint32),
        "dst_port": rng.integers(0, 65536, n).astype(np.uint32),
        "proto": rng.integers(6, 18, n).astype(np.uint32),
        "ts_us": np.sort(rng.integers(0, 1_000_000, n)).astype(np.int32),
        "pkt_len": rng.integers(40, 1500, n).astype(np.int32),
    }
