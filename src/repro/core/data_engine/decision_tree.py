"""Switch-resident lightweight decision tree (§4.1).

"For flows without a classification, a lightweight decision tree implemented
on the switch ASIC provides packet-level preliminary inference."

Branchless integer compares only (a MAT-friendly encoding): a fixed-depth
binary tree over (pkt_len, ipd) stored as flat arrays, evaluated by walking
node = 2*node + 1 + (feature >= threshold).  Trainable from data with a tiny
CART fit (numpy) — used both here and as the Leo baseline's building block.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

I32 = jnp.int32


@dataclasses.dataclass
class TreeParams:
    feature: np.ndarray    # [n_nodes] int32 feature index (internal nodes)
    threshold: np.ndarray  # [n_nodes] int32
    leaf_class: np.ndarray  # [n_leaves] int32

    @property
    def depth(self) -> int:
        return int(np.log2(len(self.leaf_class)))


def fit_tree(x: np.ndarray, y: np.ndarray, depth: int = 4,
             num_classes: int = 2, rng: Optional[np.random.Generator] = None
             ) -> TreeParams:
    """Greedy CART (gini) with integer thresholds on a complete tree."""
    n_nodes = (1 << depth) - 1
    feature = np.zeros(n_nodes, np.int32)
    threshold = np.zeros(n_nodes, np.int32)
    leaf_class = np.zeros(1 << depth, np.int32)
    idx_sets = {0: np.arange(len(y))}
    for node in range(n_nodes):
        idx = idx_sets.get(node, np.array([], np.int64))
        best = (np.inf, 0, 0)
        if len(idx) > 1:
            for f in range(x.shape[1]):
                vals = np.unique(x[idx, f])
                if len(vals) < 2:
                    continue
                cand = np.percentile(vals, [20, 35, 50, 65, 80]
                                     ).astype(np.int64)
                for th in np.unique(cand):
                    right = x[idx, f] >= th
                    g = 0.0
                    for side in (right, ~right):
                        ys = y[idx[side]]
                        if len(ys) == 0:
                            continue
                        ps = np.bincount(ys, minlength=num_classes) / len(ys)
                        g += (1 - np.sum(ps ** 2)) * len(ys)
                    if g < best[0]:
                        best = (g, f, int(th))
        feature[node], threshold[node] = best[1], best[2]
        if len(idx):
            right = x[idx, best[1]] >= best[2]
            idx_sets[2 * node + 1] = idx[~right]
            idx_sets[2 * node + 2] = idx[right]
    first_leaf = n_nodes
    for leaf in range(1 << depth):
        idx = idx_sets.get(first_leaf + leaf, np.array([], np.int64))
        if len(idx):
            leaf_class[leaf] = np.argmax(np.bincount(y[idx],
                                                     minlength=num_classes))
    return TreeParams(feature, threshold, leaf_class)


def tree_arrays(tree: TreeParams) -> Dict[str, jax.Array]:
    return {"feature": jnp.asarray(tree.feature, I32),
            "threshold": jnp.asarray(tree.threshold, I32),
            "leaf_class": jnp.asarray(tree.leaf_class, I32)}


def predict(arrs: Dict[str, jax.Array], feats: jax.Array,
            depth: int) -> jax.Array:
    """feats [..., n_feat] int32 -> class. Branchless tree walk."""
    node = jnp.zeros(feats.shape[:-1], I32)
    for _ in range(depth):
        f = arrs["feature"][node]
        th = arrs["threshold"][node]
        go_right = jnp.take_along_axis(
            feats, f[..., None], axis=-1)[..., 0] >= th
        node = 2 * node + 1 + go_right.astype(I32)
    leaf = node - (len(arrs["feature"]))
    return arrs["leaf_class"][leaf]
