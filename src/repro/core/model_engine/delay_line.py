"""Inference-result delay line: the switch<->FPGA loop latency as array state.

The host co-simulation keeps in-flight inference results in a Python list
of ``(deliver_ts, slot, hash, cls)`` tuples (``FenixSystem._inflight``).
This module is the jittable equivalent — a fixed-capacity ring whose
entries are pushed when the Model Engine finishes a batch and delivered to
the flow table once ``loop_latency_us`` has elapsed — so the whole
service/delivery loop can live inside ``lax.scan`` with no host round trip.

Delivery order matters: the host path applies results sequentially, so for
duplicate slots the *last* queued result wins (subject to the per-entry
hash ownership check).  The vectorized apply reproduces that exactly via a
stable sort by slot + last-of-run selection, which leaves unique scatter
indices (deterministic on every backend).

Push times are nondecreasing (batch timestamps are sorted and the loop
latency is constant), so the due set is always a queue prefix and head
advancement is a popcount.

Engine-farm mode tags every entry with the Model Engine that served it
(``eng`` field): results still return through the *owning pipe's* delay
line — the tag is provenance for per-engine stats, delivery semantics are
unchanged and the single-engine paths write tag 0 throughout.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

I32 = jnp.int32


def init(capacity: int) -> Dict[str, jax.Array]:
    return {
        "t": jnp.zeros((capacity,), I32),
        "slot": jnp.zeros((capacity,), I32),
        "hash": jnp.zeros((capacity,), jnp.uint32),
        "cls": jnp.zeros((capacity,), I32),
        # serving Model Engine (engine-farm tag; 0 outside farm mode)
        "eng": jnp.zeros((capacity,), I32),
        "head": jnp.asarray(0, I32),
        "tail": jnp.asarray(0, I32),
        "dropped": jnp.asarray(0, I32),
    }


def push(dl: Dict, deliver_ts: jax.Array, slots: jax.Array,
         hashes: jax.Array, cls: jax.Array, count: jax.Array,
         engines: jax.Array = None) -> Dict:
    """Append the first ``count`` lanes with delivery time ``deliver_ts``.

    ``engines`` tags each lane with the Model Engine that served it
    (engine-farm mode); the single-engine paths leave it at 0.
    """
    from repro.core.model_engine.vector_io import ring_append

    cap = dl["t"].shape[0]
    n = slots.shape[0]
    if engines is None:
        engines = jnp.zeros((n,), I32)
    valid = jnp.arange(n, dtype=I32) < count
    fields = {k: dl[k] for k in ("t", "slot", "hash", "cls", "eng")}
    values = {
        "t": jnp.broadcast_to(jnp.asarray(deliver_ts).astype(I32), (n,)),
        "slot": slots.astype(I32),
        "hash": hashes.astype(jnp.uint32),
        "cls": cls.astype(I32),
        "eng": engines.astype(I32),
    }
    out = dict(dl)
    fields, out["tail"], out["dropped"] = ring_append(
        fields, values, dl["head"], dl["tail"], dl["dropped"], cap, valid)
    out.update(fields)
    return out


def deliver(state: Dict, dl: Dict, now: jax.Array,
            n_slots: int) -> Tuple[Dict, Dict]:
    """Apply every queued result with deliver_ts <= now to the flow table.

    Matches ``FenixSystem._deliver``: each result writes ``cls`` only if the
    slot still holds the same flow hash; among duplicates the last queued
    write wins.
    """
    cap = dl["t"].shape[0]
    lane = jnp.arange(cap, dtype=I32)
    in_q = lane < (dl["tail"] - dl["head"])
    idx = jnp.mod(dl["head"] + lane, cap)
    t = dl["t"][idx]
    slots = dl["slot"][idx]
    hashes = dl["hash"][idx]
    cls = dl["cls"][idx]
    due = in_q & (t <= now.astype(I32))
    owner = state["hash"][slots] == hashes
    apply = due & owner
    # deterministic last-wins: stable-sort lanes by slot (sentinel for
    # non-applying lanes), keep the last lane of each equal-slot run
    skey = jnp.where(apply, slots, n_slots)
    order = jnp.argsort(skey, stable=True)
    s_sorted = skey[order]
    is_last = jnp.concatenate(
        [s_sorted[1:] != s_sorted[:-1], jnp.ones((1,), bool)])
    write = is_last & (s_sorted < n_slots)
    tgt = jnp.where(write, s_sorted, n_slots)
    new_state = dict(state)
    new_state["cls"] = state["cls"].at[tgt].set(cls[order], mode="drop")
    out = dict(dl)
    out["head"] = (dl["head"] + jnp.sum(due.astype(I32))).astype(I32)
    return new_state, out


def init_pipes(capacity: int, num_pipes: int) -> Dict[str, jax.Array]:
    """Per-pipe delay lines: every field gains a leading [num_pipes] dim.

    Each pipeline has its own switch<->FPGA return path, so in-flight
    results live with their owning pipe — delivery never crosses pipes.
    """
    one = init(capacity)
    return {k: jnp.stack([one[k]] * num_pipes) for k in one}


def push_pipes(dls: Dict, deliver_ts: jax.Array, slots: jax.Array,
               hashes: jax.Array, cls: jax.Array,
               counts: jax.Array, engines: jax.Array = None) -> Dict:
    """Scatter one Model-Engine result batch back to the owning pipes.

    ``slots/hashes/cls`` keep the [pipe, lane] layout of ``dequeue_pipes``
    and ``deliver_ts``/``counts`` are per-pipe, so this is a vmapped
    ``push`` — no all-gather: each pipe's results land only in its own
    delay line.  ``engines`` optionally tags lanes with the serving Model
    Engine (farm mode).
    """
    if engines is None:
        engines = jnp.zeros_like(slots, I32)
    return jax.vmap(push)(dls, deliver_ts, slots, hashes, cls, counts,
                          engines)


def deliver_pipes(states: Dict, dls: Dict, now: jax.Array,
                  local_n_slots: int) -> Tuple[Dict, Dict]:
    """Per-pipe delivery into per-pipe flow tables (vmapped ``deliver``).

    ``now`` is each pipe's own clock — pipelines advance through their own
    traffic independently.
    """
    return jax.vmap(lambda st, d, t: deliver(st, d, t, local_n_slots)
                    )(states, dls, now)


def to_list(dl: Dict) -> list:
    """Drain to the host-side list format (interop with the legacy path)."""
    import numpy as np
    head, tail = int(dl["head"]), int(dl["tail"])
    cap = dl["t"].shape[0]
    idx = (head + np.arange(tail - head)) % cap
    t, slot = np.asarray(dl["t"]), np.asarray(dl["slot"])
    h, cls = np.asarray(dl["hash"]), np.asarray(dl["cls"])
    return [(int(t[i]), int(slot[i]), int(h[i]), int(cls[i])) for i in idx]
