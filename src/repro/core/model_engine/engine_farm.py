"""Model-Engine Farm (§7 scale-out): E FPGA engines behind one switch.

FENIX's discussion points at the natural scale-out beyond one ZU19EG:
several FPGA Model Engines served by one switch.  This module makes that a
first-class subsystem rather than a loop around ``EngineModel.infer``:

* **Topology.**  An ``"engine"`` mesh axis *orthogonal* to the existing
  ``"pipe"`` axis: ``farm_mesh`` builds a 2-D ``(num_pipes, num_engines)``
  device mesh when enough devices are up, and the same per-(pipe, engine)
  cell function runs under nested ``vmap`` (with both axis names) on hosts
  below ``P * E`` devices.

* **Dataflow.**  Each pipe's Data Engine and Vector-I/O ring stay exactly
  as in the multi-pipeline driver.  The pipes' dequeued lanes are routed
  to per-engine *ingress* FIFOs by an occupancy-based router
  (``vio.engine_intake`` — the ``pipe_shares`` waterfall with engines as
  the consumers: the least-loaded engine takes the most lanes, and no lane
  is ever assigned beyond an engine's free capacity).  Every engine then
  drains its own ingress queue against its own per-engine service budget
  (the single-engine ``vio.step_budget``), runs its inference batch, and
  the verdicts scatter back through the *owning pipe's* delay line, tagged
  with the serving engine.

* **Collectives.**  Four per step, all static-shaped: one scalar
  ``[occupancy, t0, t1]`` all-gather over ``"pipe"`` (as in the pipes
  driver), one scalar free-space all-gather over ``"engine"``, one lane
  all-gather over ``"pipe"`` (features must reach their engine — the one
  place lane data crosses the mesh), and one result all-gather over
  ``"engine"`` (ids + classes only, no features).

``num_engines=1`` forced through the farm path is bit-identical to the
multi-pipeline driver (asserted in tests/test_engine_farm.py): the single
engine's ingress queue is pass-through (everything routed is served within
the step), its budget is the pipes driver's single budget, and the engine
tag is 0 everywhere.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec

try:                                    # moved out of experimental in newer jax
    from jax import shard_map           # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map

from repro.core.data_engine import rate_limiter as rl
from repro.core.model_engine import delay_line as dl
from repro.core.model_engine import vector_io as vio

I32 = jnp.int32

# engine ingress queue-depth histogram: log2 buckets 0, 1, 2-3, 4-7, ...
DEPTH_BUCKETS = 16
_DEPTH_EDGES = np.asarray([1 << b for b in range(DEPTH_BUCKETS - 1)],
                          np.int64)


def farm_mesh(num_pipes: int, num_engines: int) -> Optional[Mesh]:
    """2-D ``(pipe, engine)`` device mesh, or None for the vmap fallback.

    One device per (pipeline, engine) cell — on CPU CI these are the
    ``--xla_force_host_platform_device_count`` virtual devices.  Hosts
    with fewer than ``num_pipes * num_engines`` devices run the same cell
    function under nested ``vmap`` on one device instead.
    """
    devs = jax.devices()
    need = num_pipes * num_engines
    if len(devs) >= need:
        return Mesh(np.asarray(devs[:need]).reshape(num_pipes, num_engines),
                    ("pipe", "engine"))
    return None


def depth_histogram(depths: np.ndarray,
                    num_engines: int) -> List[List[int]]:
    """Per-engine log2 histogram of ingress queue-depth samples.

    ``depths`` is [n_samples, num_engines]; bucket b counts samples in
    [2^(b-1), 2^b) (bucket 0 is depth 0), saturating at the last bucket.
    """
    depths = np.asarray(depths, np.int64).reshape(-1, num_engines)
    hist = np.zeros((num_engines, DEPTH_BUCKETS), np.int64)
    for e in range(num_engines):
        b = np.searchsorted(_DEPTH_EDGES, depths[:, e], side="right")
        hist[e] = np.bincount(b, minlength=DEPTH_BUCKETS)
    return hist.tolist()


def route_ranks(shares: jax.Array, lanes: int,
                start: jax.Array, take: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Resolve one engine's intake ranks to (pipe, lane, valid) coordinates.

    The step's routed lanes form one pipe-major sequence: pipe p
    contributes its ``shares[p]`` dequeued lanes (FIFO order) at global
    ranks ``[offset_p, offset_p + shares[p])``.  Engine e takes the rank
    window ``[start, start + take)``; this maps each of its ``lanes``
    intake positions back to the owning (pipe, lane-within-pipe) pair.
    """
    csum = jnp.cumsum(shares)
    offs = csum - shares
    k = jnp.arange(lanes, dtype=I32)
    rank = start.astype(I32) + k
    pipe = jnp.searchsorted(csum, rank, side="right").astype(I32)
    pipe_c = jnp.minimum(pipe, shares.shape[0] - 1)
    lane = rank - offs[pipe_c]
    return pipe_c, lane, k < take


def gather_results(res_pipe: jax.Array, res_n: jax.Array,
                   my_pipe: jax.Array,
                   values: Tuple[jax.Array, ...]
                   ) -> Tuple[Tuple[jax.Array, ...], jax.Array]:
    """Select one pipe's results from the all-gathered [E, S] farm output.

    Flattens engine-major (engine order, then each engine's FIFO service
    order — deterministic), packs the lanes owned by ``my_pipe`` to the
    front, and returns the packed value arrays plus the count.  Each value
    array keeps its [E, S] shape flattened to [E * S].
    """
    e, s = res_pipe.shape
    lane_ok = jnp.arange(s, dtype=I32)[None, :] < res_n[:, None]
    mine = (lane_ok & (res_pipe == my_pipe)).reshape(-1)
    rank = jnp.cumsum(mine.astype(I32))
    dest = jnp.where(mine, rank - 1, e * s)
    packed = tuple(
        jnp.zeros((e * s,), v.dtype).at[dest].set(v.reshape(-1),
                                                  mode="drop")
        for v in values)
    return packed, jnp.sum(mine.astype(I32))


def make_farm_step(num_pipes: int, num_engines: int, iocfg: vio.IOConfig,
                   base_rate_per_us: float, loop_latency_us: int,
                   de_local, model, mesh: Optional[Mesh], masked: bool,
                   local_cfg=None):
    """One scan step of the farm driver: sharded pipes feeding E engines.

    ``de_local`` is the pipe-local Data-Engine body (built by
    ``fenix._make_pipe_local`` from the per-pipe local config);
    ``base_rate_per_us`` is the SINGLE-engine global service rate — each
    engine's budget uses it directly, so the farm's aggregate service is
    ``num_engines`` times the pipes driver's single budget and
    ``num_engines=1`` reproduces that budget bit-for-bit.

    ``local_cfg`` is the per-pipe ``EngineConfig`` the in-scan control
    plane rebuilds each pipe's admission LUT with when the chunk's
    ``"_cp"`` flag marks a T_w window boundary (``lax.cond`` at the end of
    the cell, after the freeze-select, so frozen pipes roll their windows
    too — exactly when the old host-side rebuild ran).  The update is a
    pure function of the pipe's own switch state, so it is engine-invariant
    by construction (required: ``pstate`` is replicated over the
    ``"engine"`` axis).

    The cell function below is written per (pipe, engine) coordinate and
    runs either under ``shard_map`` on the 2-D mesh or under nested
    ``vmap`` with the same axis names.  Values that only vary along one
    axis stay unbatched along the other (vmap) / replicated (shard_map),
    so the Data Engine is computed once per pipe and the service once per
    engine in both modes.

    ``masked=True`` compiles the traffic-skew variant: a pipe whose stream
    is exhausted replays a dummy batch with its switch state frozen and
    zero merge weight.  The engines keep draining backlog during such
    steps; results owned by a frozen pipe are still pushed to its delay
    line (they are real results of earlier real batches), timestamped with
    the farm-wide clock ``max_p(now_p)`` instead of the frozen pipe's
    dummy clock.
    """
    imax = jnp.iinfo(jnp.int32)
    serve_lanes = vio.engine_serve_lanes(iocfg, num_pipes)

    def cell_step(pstate, pqueues, pdline, eq, chunk):
        cp = chunk["_cp"]
        # -- pipe-local switch stage (varies over "pipe" only) --------------
        if masked:
            active = chunk["_active"]
            chunk = {k: v for k, v in chunk.items() if k != "_active"}
        new_s, new_q, new_d, aux = de_local(pstate, pqueues, pdline, chunk)
        if masked:
            pstate, pqueues, pdline = jax.tree.map(
                lambda nu, old: jnp.where(active, nu, old),
                (new_s, new_q, new_d), (pstate, pqueues, pdline))
            occ_self = (pqueues["tail"] - pqueues["head"]) \
                * active.astype(I32)
            lo_self = jnp.where(active, aux["ts_first"], imax.max)
            hi_self = jnp.where(active, aux["now"], imax.min)
        else:
            pstate, pqueues, pdline = new_s, new_q, new_d
            occ_self = pqueues["tail"] - pqueues["head"]
            lo_self, hi_self = aux["ts_first"], aux["now"]
        gath = jax.lax.all_gather(
            jnp.stack([occ_self, lo_self, hi_self]), "pipe")    # [P, 3]
        hi = jnp.max(gath[:, 2])
        # -- per-engine service budget (the farm's one step_budget site) ----
        ebudget = vio.step_budget(jnp.min(gath[:, 1]), hi,
                                  base_rate_per_us,
                                  num_pipes * iocfg.queue_len)
        free_self = vio.engine_free(eq, iocfg, num_pipes)
        freeg = jax.lax.all_gather(free_self, "engine")         # [E]
        # pipes dequeue against the farm's pooled budget, capped by the
        # total ingress space so the router can always place every lane
        take_total = jnp.minimum(num_engines * ebudget, jnp.sum(freeg))
        shares = vio.pipe_shares(gath[:, 0], take_total)        # [P]
        # actual per-pipe dequeues: dequeue_device additionally caps each
        # share at serve_lanes (same as the pipes driver); the router must
        # see the capped counts or it would route phantom lanes.  Every
        # cell derives them from the gathered scalars — no extra collective
        counts = jnp.minimum(shares, iocfg.serve_lanes)         # [P]
        my_share = shares[jax.lax.axis_index("pipe")]
        pqueues, s_de, h_de, f_de, _ = vio.dequeue_device(pqueues, iocfg,
                                                          my_share)
        # -- route lanes to engines (the one lane-data collective; slot,
        # hash, and features pack into a single [L, 2+K] int32 gather —
        # int32<->uint32 casts round-trip bitwise) -------------------------
        lane_pack = jnp.concatenate(
            [s_de[:, None], h_de.astype(I32)[:, None],
             f_de.reshape(f_de.shape[0], -1)], axis=1)
        lanes = jax.lax.all_gather(lane_pack, "pipe")       # [P, L, 2+K]
        intake = vio.engine_intake(freeg, jnp.sum(counts))      # [E]
        e_idx = jax.lax.axis_index("engine")
        estart = (jnp.cumsum(intake) - intake)[e_idx]
        pipe_of, lane_of, valid_in = route_ranks(
            counts, serve_lanes, estart, intake[e_idx])
        flat = pipe_of * iocfg.serve_lanes + lane_of
        sel = lanes.reshape(num_pipes * iocfg.serve_lanes, -1)[flat]
        eq = vio.enqueue_engine(
            eq, iocfg, num_pipes, valid_in,
            sel[:, 0], sel[:, 1].astype(jnp.uint32),
            sel[:, 2:].reshape(serve_lanes, iocfg.feat_len,
                               iocfg.feat_dim),
            pipe_of)
        # -- per-engine service (varies over "engine" only) -----------------
        eq, es, eh, ef, ep, srv = vio.dequeue_engine(eq, iocfg, num_pipes,
                                                     ebudget)
        ecls = model.infer(ef)
        depth_self = eq["tail"] - eq["head"]
        # -- results return through the owning pipe's delay line (the one
        # id+class collective: [slot, hash, class, pipe, count] rows) ------
        res_pack = jnp.stack([es, eh.astype(I32), ecls, ep,
                              jnp.full_like(es, srv)])          # [5, S]
        res = jax.lax.all_gather(res_pack, "engine")        # [E, 5, S]
        res_s, res_c, res_p = res[:, 0], res[:, 2], res[:, 3]
        res_h = res[:, 1].astype(jnp.uint32)
        res_n = res[:, 4, 0]
        eng_id = jnp.broadcast_to(
            jnp.arange(num_engines, dtype=I32)[:, None], res_s.shape)
        (sel_s, sel_h, sel_c, sel_e), my_cnt = gather_results(
            res_p, res_n, jax.lax.axis_index("pipe"),
            (res_s, res_h, res_c, eng_id))
        if masked:
            # frozen pipes still receive backlog verdicts; stamp them with
            # the farm-wide clock, not the dummy replay's timestamps
            push_ts = jnp.where(active, aux["now"], hi) + loop_latency_us
        else:
            push_ts = aux["now"] + loop_latency_us
        pdline = dl.push(pdline, push_ts, sel_s, sel_h, sel_c, my_cnt,
                         engines=sel_e)
        # in-scan control plane: rebuild this pipe's LUT + roll its window
        # when the chunk closes a T_w window — no host round trip
        pstate = jax.lax.cond(
            cp, lambda s: rl.control_plane_update(s, local_cfg),
            lambda s: s, pstate)
        pstats = jnp.stack([aux["granted"], aux["classified"],
                            aux["n_tree"]])
        if masked:
            pstats = pstats * active.astype(I32)
        return (pstate, pqueues, pdline, eq, aux["verdict"], pstats,
                srv, depth_self)

    if mesh is not None:
        def shard_body(pstate, pqueues, pdline, eq, chunk):
            args = jax.tree.map(lambda x: x[0],
                                (pstate, pqueues, pdline, eq, chunk))
            out = cell_step(*args)
            return jax.tree.map(lambda x: jnp.asarray(x)[None], out)

        pipe_sp, eng_sp = PartitionSpec("pipe"), PartitionSpec("engine")
        stage = shard_map(
            shard_body, mesh=mesh,
            in_specs=(pipe_sp, pipe_sp, pipe_sp, eng_sp, pipe_sp),
            out_specs=(pipe_sp, pipe_sp, pipe_sp, eng_sp, pipe_sp,
                       pipe_sp, eng_sp, eng_sp),
            # outputs are replicated along their unmentioned axis by
            # construction (deterministic compute from replicated inputs /
            # all-gathered operands); skip the static replication checker
            check_rep=False)
    else:
        inner = jax.vmap(cell_step, axis_name="engine",
                         in_axes=(None, None, None, 0, None),
                         out_axes=(None, None, None, 0, None, None, 0, 0))
        stage = jax.vmap(inner, axis_name="pipe",
                         in_axes=(0, 0, 0, None, 0),
                         out_axes=(0, 0, 0, None, 0, 0, None, None))

    def step_fn(carry, chunk):
        pstates, pqueues, pdls, eqs = carry
        (pstates, pqueues, pdls, eqs, verdict, pstats, served,
         depth) = stage(pstates, pqueues, pdls, eqs, chunk)
        return (pstates, pqueues, pdls, eqs), (verdict,
                                               pstats.sum(axis=0),
                                               served, depth)

    return step_fn


def make_farm_tail(num_pipes: int, num_engines: int, iocfg: vio.IOConfig,
                   base_rate_per_us: float, loop_latency_us: int,
                   de_local, model):
    """Per-pipe tail step of the farm driver.

    A pipe whose stream outlasts the uniform scan finishes its trailing
    (< batch) packets here, draining only its own ring against its
    1/num_pipes share of every engine's budget.  Tail lanes are served
    directly (no ingress round-trip — the scan is over, there is no later
    step to drain a queue) but still capacity-split across the engines by
    the same waterfall, so per-engine service accounting stays exact and
    every lane carries its serving-engine tag.  ``num_engines=1`` is the
    pipes driver's tail step bit-for-bit.
    """
    tail_rate = base_rate_per_us / num_pipes

    def tail_fn(carry, chunk):
        state, queues, dline = carry
        state, queues, dline, aux = de_local(state, queues, dline, chunk)
        ebudget = vio.step_budget(aux["ts_first"], aux["now"], tail_rate,
                                  iocfg.queue_len)
        queues, s2, h2, f2, cnt = vio.dequeue_device(
            queues, iocfg, num_engines * ebudget)
        assign = vio.engine_intake(
            jnp.full((num_engines,), ebudget, I32), cnt)
        tags = jnp.searchsorted(jnp.cumsum(assign),
                                jnp.arange(s2.shape[0], dtype=I32),
                                side="right").astype(I32)
        tags = jnp.minimum(tags, num_engines - 1)
        cls = model.infer(f2)
        dline = dl.push(dline, aux["now"] + loop_latency_us, s2, h2, cls,
                        cnt, engines=tags)
        stats = jnp.stack([aux["granted"], cnt, aux["classified"],
                           aux["n_tree"]])
        return (state, queues, dline), (aux["verdict"], stats, assign)

    return tail_fn
