"""Vector I/O Processor (§5.1): flow-identifier FIFO + result pairing.

The FPGA parses mirror packets into (flow id, feature vector); ids wait in a
FIFO while vectors run through the DNN; completed inferences are paired with
the id at the FIFO head and shipped back to the switch.  FIFOs are fixed
arrays + head/tail counters (the asynchronous-FIFO clock-domain decoupling
becomes explicit queue state in the co-simulation).

Two interchangeable implementations share the queue-state dict:

* ``enqueue_batch`` / ``dequeue_batch`` — host-side (NumPy loop) reference,
  kept for the step-by-step co-simulation and as the oracle in tests.
* ``enqueue_device`` / ``dequeue_device`` — jittable masked-scatter
  versions with identical FIFO/drop semantics, usable inside ``lax.scan``
  (the Tbps trace driver).  Dequeue returns fixed-shape lanes
  (``serve_max``) plus a count so downstream shapes stay static.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class IOConfig:
    queue_len: int = 1024
    feat_len: int = 9
    feat_dim: int = 2
    # static per-step dequeue lane count for the device path; None means
    # queue_len, which makes dequeue_device bit-identical to the host loop
    # (occupancy never exceeds queue_len).  Set lower to trade a service
    # cap for less padded Model-Engine compute per step.
    serve_max: Optional[int] = None

    @property
    def serve_lanes(self) -> int:
        return self.queue_len if self.serve_max is None else self.serve_max


def init_queues(cfg: IOConfig) -> Dict[str, jax.Array]:
    return {
        "id_q_slot": jnp.zeros((cfg.queue_len,), I32),
        "id_q_hash": jnp.zeros((cfg.queue_len,), jnp.uint32),
        "feat_q": jnp.zeros((cfg.queue_len, cfg.feat_len, cfg.feat_dim),
                            I32),
        "head": jnp.asarray(0, I32),
        "tail": jnp.asarray(0, I32),
        "dropped": jnp.asarray(0, I32),
    }


def enqueue_batch(q: Dict, cfg: IOConfig, slots: np.ndarray,
                  hashes: np.ndarray, feats: np.ndarray) -> Dict:
    """Host-side co-sim: append granted mirror packets; drop on overflow."""
    head, tail = int(q["head"]), int(q["tail"])
    cap = cfg.queue_len
    out = {k: np.array(v) for k, v in q.items()}  # writable copies
    dropped = int(q["dropped"])
    for i in range(len(slots)):
        if tail - head >= cap:
            dropped += 1
            continue
        pos = tail % cap
        out["id_q_slot"][pos] = slots[i]
        out["id_q_hash"][pos] = hashes[i]
        out["feat_q"][pos] = feats[i]
        tail += 1
    out["head"], out["tail"] = head, tail
    out["dropped"] = dropped
    return {k: jnp.asarray(v) for k, v in out.items()}


def dequeue_batch(q: Dict, cfg: IOConfig, n: int
                  ) -> Tuple[Dict, np.ndarray, np.ndarray, np.ndarray]:
    """Pop up to n entries in FIFO order (ordering invariant of §5.1)."""
    head, tail = int(q["head"]), int(q["tail"])
    take = min(n, tail - head)
    cap = cfg.queue_len
    idx = (head + np.arange(take)) % cap
    slots = np.asarray(q["id_q_slot"])[idx]
    hashes = np.asarray(q["id_q_hash"])[idx]
    feats = np.asarray(q["feat_q"])[idx]
    out = dict(q)
    out["head"] = jnp.asarray(head + take, I32)
    return out, slots, hashes, feats


def occupancy(q: Dict) -> int:
    return int(q["tail"]) - int(q["head"])


# -- device-resident (jittable) FIFO ops ------------------------------------

def ring_append(fields: Dict[str, jax.Array], values: Dict[str, jax.Array],
                head: jax.Array, tail: jax.Array, dropped: jax.Array,
                cap: int, valid: jax.Array
                ) -> Tuple[Dict[str, jax.Array], jax.Array, jax.Array]:
    """Masked append of ``values`` lanes into ring-buffer ``fields``.

    Valid lanes are packed in lane order; lanes that would overflow the
    ring are counted into ``dropped`` (same semantics as the host loop).
    Shared by the Vector I/O FIFO and the inference delay line.  Returns
    (fields', tail', dropped').
    """
    rank = jnp.cumsum(valid.astype(I32))          # 1-based among valid lanes
    fits = valid & (tail + rank - head <= cap)
    # ring position for accepted lanes; cap (out of range) drops the rest
    pos = jnp.where(fits, jnp.mod(tail + rank - 1, cap), cap)
    out = {k: fields[k].at[pos].set(values[k], mode="drop") for k in fields}
    n_in = jnp.sum(fits.astype(I32))
    n_dropped = (dropped + jnp.sum(valid.astype(I32)) - n_in).astype(I32)
    return out, (tail + n_in).astype(I32), n_dropped


def service_budget(span_us, rate_per_us: float, cap: int) -> jax.Array:
    """Model-Engine inferences servable in ``span_us``: clip(V*span, 1, cap).

    One shared (jittable, float32) formula so the host loop and the device
    scan agree bit-for-bit.  ``cap`` at queue_len loses nothing — dequeue
    is bounded by occupancy <= queue_len anyway — and keeps the product
    inside int32 range.
    """
    b = jnp.floor(jnp.asarray(span_us).astype(jnp.float32)
                  * jnp.float32(rate_per_us))
    return jnp.clip(b, 1, cap).astype(I32)


def enqueue_device(q: Dict, cfg: IOConfig, valid: jax.Array,
                   slots: jax.Array, hashes: jax.Array,
                   feats: jax.Array) -> Dict:
    """Masked vectorized enqueue: same FIFO/drop semantics as the host loop.

    ``valid`` [n] selects lanes to append (in lane order); lanes that would
    overflow the ring are counted in ``dropped`` exactly like the host path.
    """
    fields = {k: q[k] for k in ("id_q_slot", "id_q_hash", "feat_q")}
    values = {"id_q_slot": slots.astype(I32),
              "id_q_hash": hashes.astype(jnp.uint32),
              "feat_q": feats.astype(I32)}
    out = dict(q)
    fields, out["tail"], out["dropped"] = ring_append(
        fields, values, q["head"], q["tail"], q["dropped"],
        cfg.queue_len, valid)
    out.update(fields)
    return out


def dequeue_device(q: Dict, cfg: IOConfig, budget: jax.Array
                   ) -> Tuple[Dict, jax.Array, jax.Array, jax.Array,
                              jax.Array]:
    """Pop min(budget, occupancy, serve_max) entries in FIFO order.

    Returns (q', slots[serve_lanes], hashes[serve_lanes],
    feats[serve_lanes, ...], count); lanes >= count are zero-filled.
    """
    cap = cfg.queue_len
    head, tail = q["head"], q["tail"]
    take = jnp.minimum(jnp.minimum(budget.astype(I32), tail - head),
                       cfg.serve_lanes)
    lane = jnp.arange(cfg.serve_lanes, dtype=I32)
    idx = jnp.where(lane < take, jnp.mod(head + lane, cap), cap)
    slots = q["id_q_slot"].at[idx].get(mode="fill", fill_value=0)
    hashes = q["id_q_hash"].at[idx].get(mode="fill", fill_value=0)
    feats = q["feat_q"].at[idx].get(mode="fill", fill_value=0)
    out = dict(q)
    out["head"] = (head + take).astype(I32)
    return out, slots, hashes, feats, take
