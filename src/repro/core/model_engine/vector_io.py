"""Vector I/O Processor (§5.1): flow-identifier FIFO + result pairing.

The FPGA parses mirror packets into (flow id, feature vector); ids wait in a
FIFO while vectors run through the DNN; completed inferences are paired with
the id at the FIFO head and shipped back to the switch.  FIFOs are fixed
arrays + head/tail counters (the asynchronous-FIFO clock-domain decoupling
becomes explicit queue state in the co-simulation).

Two interchangeable implementations share the queue-state dict:

* ``enqueue_batch`` / ``dequeue_batch`` — host-side (NumPy loop) reference,
  kept for the step-by-step co-simulation and as the oracle in tests.
* ``enqueue_device`` / ``dequeue_device`` — jittable masked-scatter
  versions with identical FIFO/drop semantics, usable inside ``lax.scan``
  (the Tbps trace driver).  Dequeue returns fixed-shape lanes
  (``serve_max``) plus a count so downstream shapes stay static.

Multi-pipeline merge: with ``num_pipes`` switch pipelines feeding one FPGA
Model Engine, each pipe keeps its *own* FIFO (enqueue stays pipe-local,
inside the shard), and the single service budget is split across the pipes'
rings by ``pipe_shares`` — an occupancy-weighted round-robin built from
static ``lax`` ops (proportional base share + pipe-ordered waterfall for
the integer remainder).  ``dequeue_pipes`` then drains each ring by its
share; the dequeued lanes keep their [pipe, lane] layout, so inference
results scatter straight back to the owning pipe's delay line with no
all-gather of ring contents.

Engine-farm ingress (§7 scale-out, ISSUE 3): with ``num_engines`` FPGA
Model Engines behind the switch, each engine owns an *ingress* FIFO on the
FPGA side of the interconnect (``init_engine_queues``).  The pipes'
dequeued lanes are routed to engines by the same share/waterfall math with
the roles flipped — ``engine_intake`` weights by each engine's free
ingress space (the least-loaded engine takes the most lanes) and never
assigns a lane beyond an engine's remaining capacity.  Ingress entries
carry the owning pipe id so completed inferences scatter back to that
pipe's delay line, tagged with the serving engine.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class IOConfig:
    queue_len: int = 1024
    feat_len: int = 9
    feat_dim: int = 2
    # static per-step dequeue lane count for the device path; None means
    # queue_len, which makes dequeue_device bit-identical to the host loop
    # (occupancy never exceeds queue_len).  Set lower to trade a service
    # cap for less padded Model-Engine compute per step.
    serve_max: Optional[int] = None

    @property
    def serve_lanes(self) -> int:
        return self.queue_len if self.serve_max is None else self.serve_max


def init_queues(cfg: IOConfig) -> Dict[str, jax.Array]:
    return {
        "id_q_slot": jnp.zeros((cfg.queue_len,), I32),
        "id_q_hash": jnp.zeros((cfg.queue_len,), jnp.uint32),
        "feat_q": jnp.zeros((cfg.queue_len, cfg.feat_len, cfg.feat_dim),
                            I32),
        "head": jnp.asarray(0, I32),
        "tail": jnp.asarray(0, I32),
        "dropped": jnp.asarray(0, I32),
    }


def enqueue_batch(q: Dict, cfg: IOConfig, slots: np.ndarray,
                  hashes: np.ndarray, feats: np.ndarray) -> Dict:
    """Host-side co-sim: append granted mirror packets; drop on overflow."""
    head, tail = int(q["head"]), int(q["tail"])
    cap = cfg.queue_len
    out = {k: np.array(v) for k, v in q.items()}  # writable copies
    dropped = int(q["dropped"])
    for i in range(len(slots)):
        if tail - head >= cap:
            dropped += 1
            continue
        pos = tail % cap
        out["id_q_slot"][pos] = slots[i]
        out["id_q_hash"][pos] = hashes[i]
        out["feat_q"][pos] = feats[i]
        tail += 1
    out["head"], out["tail"] = head, tail
    out["dropped"] = dropped
    return {k: jnp.asarray(v) for k, v in out.items()}


def dequeue_batch(q: Dict, cfg: IOConfig, n: int
                  ) -> Tuple[Dict, np.ndarray, np.ndarray, np.ndarray]:
    """Pop up to n entries in FIFO order (ordering invariant of §5.1)."""
    head, tail = int(q["head"]), int(q["tail"])
    take = min(n, tail - head)
    cap = cfg.queue_len
    idx = (head + np.arange(take)) % cap
    slots = np.asarray(q["id_q_slot"])[idx]
    hashes = np.asarray(q["id_q_hash"])[idx]
    feats = np.asarray(q["feat_q"])[idx]
    out = dict(q)
    out["head"] = jnp.asarray(head + take, I32)
    return out, slots, hashes, feats


def occupancy(q: Dict) -> int:
    return int(q["tail"]) - int(q["head"])


# -- device-resident (jittable) FIFO ops ------------------------------------

def ring_append(fields: Dict[str, jax.Array], values: Dict[str, jax.Array],
                head: jax.Array, tail: jax.Array, dropped: jax.Array,
                cap: int, valid: jax.Array
                ) -> Tuple[Dict[str, jax.Array], jax.Array, jax.Array]:
    """Masked append of ``values`` lanes into ring-buffer ``fields``.

    Valid lanes are packed in lane order; lanes that would overflow the
    ring are counted into ``dropped`` (same semantics as the host loop).
    Shared by the Vector I/O FIFO and the inference delay line.  Returns
    (fields', tail', dropped').
    """
    rank = jnp.cumsum(valid.astype(I32))          # 1-based among valid lanes
    fits = valid & (tail + rank - head <= cap)
    # ring position for accepted lanes; cap (out of range) drops the rest
    pos = jnp.where(fits, jnp.mod(tail + rank - 1, cap), cap)
    out = {k: fields[k].at[pos].set(values[k], mode="drop") for k in fields}
    n_in = jnp.sum(fits.astype(I32))
    n_dropped = (dropped + jnp.sum(valid.astype(I32)) - n_in).astype(I32)
    return out, (tail + n_in).astype(I32), n_dropped


def ring_pop(fields: Dict[str, jax.Array], head: jax.Array,
             tail: jax.Array, cap: int, budget: jax.Array, lanes: int
             ) -> Tuple[Dict[str, jax.Array], jax.Array, jax.Array]:
    """Pop min(budget, occupancy, lanes) ring entries in FIFO order.

    The dequeue twin of ``ring_append``, shared by the Vector-I/O FIFO
    and the engine ingress queues: returns ([lanes]-shaped value arrays
    with positions >= count zero-filled, head', count).
    """
    take = jnp.minimum(jnp.minimum(budget.astype(I32), tail - head),
                       lanes)
    lane = jnp.arange(lanes, dtype=I32)
    idx = jnp.where(lane < take, jnp.mod(head + lane, cap), cap)
    vals = {k: v.at[idx].get(mode="fill", fill_value=0)
            for k, v in fields.items()}
    return vals, (head + take).astype(I32), take


def service_budget(span_us, rate_per_us: float, cap: int) -> jax.Array:
    """Model-Engine inferences servable in ``span_us``: clip(V*span, 1, cap).

    One shared (jittable, float32) formula so the host loop and the device
    scan agree bit-for-bit.  ``cap`` at queue_len loses nothing — dequeue
    is bounded by occupancy <= queue_len anyway — and keeps the product
    inside int32 range.
    """
    b = jnp.floor(jnp.asarray(span_us).astype(jnp.float32)
                  * jnp.float32(rate_per_us))
    return jnp.clip(b, 1, cap).astype(I32)


def step_budget(ts_first, ts_last, rate_per_us: float, cap: int) -> jax.Array:
    """Service budget for one step spanning [ts_first, ts_last].

    The span->budget composition used (identically) by the host loop, the
    device scan, and the multi-pipe driver — one call site for the float32
    formula so every path agrees bit-for-bit.
    """
    span = jnp.maximum(jnp.asarray(ts_last).astype(I32)
                       - jnp.asarray(ts_first).astype(I32), 1)
    return service_budget(span, rate_per_us, cap)


def init_pipes_queues(cfg: IOConfig, num_pipes: int) -> Dict[str, jax.Array]:
    """Per-pipe FIFOs: every queue field gains a leading [num_pipes] dim."""
    one = init_queues(cfg)
    return {k: jnp.stack([one[k]] * num_pipes) for k in one}


def pipe_shares(occ: jax.Array, budget: jax.Array) -> jax.Array:
    """Split one Model-Engine ``budget`` across pipes by ring occupancy.

    Occupancy-weighted round-robin with static ops only: every pipe first
    gets ``floor(budget * occ_p / sum(occ))`` (capped at its occupancy),
    then the integer remainder waterfalls through the pipes in index order
    until it is spent.  Guarantees ``share_p <= occ_p`` and
    ``sum(share) == min(budget, sum(occ))``; a single pipe degenerates to
    ``min(budget, occ)`` — the single-pipe dequeue take.
    """
    occ = jnp.maximum(occ.astype(I32), 0)
    budget = budget.astype(I32)
    total = jnp.sum(occ)
    # budget*occ reaches num_pipes*queue_len^2 — widen so large queue_len
    # configs cannot wrap int32 into negative shares.  Without x64 the
    # astype would silently truncate back to int32 (and warn on every
    # trace), so only request the wide dtype when it actually exists.
    wide = jnp.int64 if jax.config.jax_enable_x64 else I32
    base = jnp.minimum((budget.astype(wide) * occ.astype(wide)
                        // jnp.maximum(total, 1).astype(wide)
                        ).astype(I32), occ)
    leftover = jnp.maximum(budget - jnp.sum(base), 0)
    room = occ - base
    before = jnp.cumsum(room) - room          # room in earlier pipes
    extra = jnp.clip(leftover - before, 0, room)
    return base + extra


def dequeue_pipes(q: Dict, cfg: IOConfig, shares: jax.Array
                  ) -> Tuple[Dict, jax.Array, jax.Array, jax.Array,
                             jax.Array]:
    """Drain each pipe's ring by its share (vmapped ``dequeue_device``).

    Returns (q', slots[P, lanes], hashes[P, lanes], feats[P, lanes, ...],
    counts[P]); the [pipe, lane] layout keys results back to the owning
    pipe without gathering ring contents across pipes.
    """
    return jax.vmap(lambda qp, s: dequeue_device(qp, cfg, s),
                    in_axes=(0, 0))(q, shares)


def enqueue_device(q: Dict, cfg: IOConfig, valid: jax.Array,
                   slots: jax.Array, hashes: jax.Array,
                   feats: jax.Array) -> Dict:
    """Masked vectorized enqueue: same FIFO/drop semantics as the host loop.

    ``valid`` [n] selects lanes to append (in lane order); lanes that would
    overflow the ring are counted in ``dropped`` exactly like the host path.
    """
    fields = {k: q[k] for k in ("id_q_slot", "id_q_hash", "feat_q")}
    values = {"id_q_slot": slots.astype(I32),
              "id_q_hash": hashes.astype(jnp.uint32),
              "feat_q": feats.astype(I32)}
    out = dict(q)
    fields, out["tail"], out["dropped"] = ring_append(
        fields, values, q["head"], q["tail"], q["dropped"],
        cfg.queue_len, valid)
    out.update(fields)
    return out


# -- engine-farm ingress FIFOs (one per Model Engine) ------------------------

def engine_capacity(cfg: IOConfig, num_pipes: int) -> int:
    """Per-engine ingress capacity: enough to absorb every pipe's ring."""
    return num_pipes * cfg.queue_len


def engine_serve_lanes(cfg: IOConfig, num_pipes: int) -> int:
    """Static per-step service lane count of one engine.

    ``num_pipes * serve_lanes`` bounds the lanes a single step can route
    (each pipe dequeues at most ``serve_lanes``), so one engine serving a
    whole step's intake — the ``num_engines=1`` identity case — never
    leaves a routed lane waiting.
    """
    return num_pipes * cfg.serve_lanes


def init_engine_queues(cfg: IOConfig, num_engines: int,
                       num_pipes: int) -> Dict[str, jax.Array]:
    """Per-engine ingress FIFOs: (slot, hash, feat, owning pipe) entries."""
    cap = engine_capacity(cfg, num_pipes)
    one = {
        "eq_slot": jnp.zeros((cap,), I32),
        "eq_hash": jnp.zeros((cap,), jnp.uint32),
        "eq_feat": jnp.zeros((cap, cfg.feat_len, cfg.feat_dim), I32),
        "eq_pipe": jnp.zeros((cap,), I32),
        "head": jnp.asarray(0, I32),
        "tail": jnp.asarray(0, I32),
        "dropped": jnp.asarray(0, I32),
    }
    return {k: jnp.stack([one[k]] * num_engines) for k in one}


def engine_free(eq: Dict, cfg: IOConfig, num_pipes: int) -> jax.Array:
    """Remaining ingress space of one engine's queue slice."""
    return (jnp.asarray(engine_capacity(cfg, num_pipes), I32)
            - (eq["tail"] - eq["head"]))


def engine_intake(free: jax.Array, n_lanes: jax.Array) -> jax.Array:
    """Split ``n_lanes`` routed lanes across engines by free ingress space.

    The ``pipe_shares`` waterfall with the roles flipped — engines are the
    *consumers*: each engine first gets ``floor(n * free_e / sum(free))``
    (the least-loaded engine takes the most lanes), the integer remainder
    waterfalls in engine order.  Guarantees ``intake_e <= free_e`` (the
    router never assigns beyond an engine's capacity) and
    ``sum(intake) == min(n_lanes, sum(free))``.
    """
    return pipe_shares(free, n_lanes)


def enqueue_engine(eq: Dict, cfg: IOConfig, num_pipes: int,
                   valid: jax.Array, slots: jax.Array, hashes: jax.Array,
                   feats: jax.Array, pipes: jax.Array) -> Dict:
    """Masked append into one engine's ingress ring (FIFO/drop semantics)."""
    fields = {k: eq[k] for k in ("eq_slot", "eq_hash", "eq_feat", "eq_pipe")}
    values = {"eq_slot": slots.astype(I32),
              "eq_hash": hashes.astype(jnp.uint32),
              "eq_feat": feats.astype(I32),
              "eq_pipe": pipes.astype(I32)}
    out = dict(eq)
    fields, out["tail"], out["dropped"] = ring_append(
        fields, values, eq["head"], eq["tail"], eq["dropped"],
        engine_capacity(cfg, num_pipes), valid)
    out.update(fields)
    return out


def dequeue_engine(eq: Dict, cfg: IOConfig, num_pipes: int,
                   budget: jax.Array
                   ) -> Tuple[Dict, jax.Array, jax.Array, jax.Array,
                              jax.Array, jax.Array]:
    """Pop min(budget, occupancy, serve lanes) ingress entries, FIFO order.

    Returns (eq', slots[S], hashes[S], feats[S, ...], pipes[S], count) with
    ``S = engine_serve_lanes``; lanes >= count are zero-filled.
    """
    vals, head, take = ring_pop(
        {k: eq[k] for k in ("eq_slot", "eq_hash", "eq_feat", "eq_pipe")},
        eq["head"], eq["tail"], engine_capacity(cfg, num_pipes), budget,
        engine_serve_lanes(cfg, num_pipes))
    out = dict(eq)
    out["head"] = head
    return (out, vals["eq_slot"], vals["eq_hash"], vals["eq_feat"],
            vals["eq_pipe"], take)


def dequeue_device(q: Dict, cfg: IOConfig, budget: jax.Array
                   ) -> Tuple[Dict, jax.Array, jax.Array, jax.Array,
                              jax.Array]:
    """Pop min(budget, occupancy, serve_max) entries in FIFO order.

    Returns (q', slots[serve_lanes], hashes[serve_lanes],
    feats[serve_lanes, ...], count); lanes >= count are zero-filled.
    """
    vals, head, take = ring_pop(
        {k: q[k] for k in ("id_q_slot", "id_q_hash", "feat_q")},
        q["head"], q["tail"], cfg.queue_len, budget, cfg.serve_lanes)
    out = dict(q)
    out["head"] = head
    return (out, vals["id_q_slot"], vals["id_q_hash"], vals["feat_q"],
            take)
