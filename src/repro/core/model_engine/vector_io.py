"""Vector I/O Processor (§5.1): flow-identifier FIFO + result pairing.

The FPGA parses mirror packets into (flow id, feature vector); ids wait in a
FIFO while vectors run through the DNN; completed inferences are paired with
the id at the FIFO head and shipped back to the switch.  FIFOs are fixed
arrays + head/tail counters (the asynchronous-FIFO clock-domain decoupling
becomes explicit queue state in the co-simulation).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class IOConfig:
    queue_len: int = 1024
    feat_len: int = 9
    feat_dim: int = 2


def init_queues(cfg: IOConfig) -> Dict[str, jax.Array]:
    return {
        "id_q_slot": jnp.zeros((cfg.queue_len,), I32),
        "id_q_hash": jnp.zeros((cfg.queue_len,), jnp.uint32),
        "feat_q": jnp.zeros((cfg.queue_len, cfg.feat_len, cfg.feat_dim),
                            I32),
        "head": jnp.asarray(0, I32),
        "tail": jnp.asarray(0, I32),
        "dropped": jnp.asarray(0, I32),
    }


def enqueue_batch(q: Dict, cfg: IOConfig, slots: np.ndarray,
                  hashes: np.ndarray, feats: np.ndarray) -> Dict:
    """Host-side co-sim: append granted mirror packets; drop on overflow."""
    head, tail = int(q["head"]), int(q["tail"])
    cap = cfg.queue_len
    out = {k: np.array(v) for k, v in q.items()}  # writable copies
    dropped = int(q["dropped"])
    for i in range(len(slots)):
        if tail - head >= cap:
            dropped += 1
            continue
        pos = tail % cap
        out["id_q_slot"][pos] = slots[i]
        out["id_q_hash"][pos] = hashes[i]
        out["feat_q"][pos] = feats[i]
        tail += 1
    out["head"], out["tail"] = head, tail
    out["dropped"] = dropped
    return {k: jnp.asarray(v) for k, v in out.items()}


def dequeue_batch(q: Dict, cfg: IOConfig, n: int
                  ) -> Tuple[Dict, np.ndarray, np.ndarray, np.ndarray]:
    """Pop up to n entries in FIFO order (ordering invariant of §5.1)."""
    head, tail = int(q["head"]), int(q["tail"])
    take = min(n, tail - head)
    cap = cfg.queue_len
    idx = (head + np.arange(take)) % cap
    slots = np.asarray(q["id_q_slot"])[idx]
    hashes = np.asarray(q["id_q_hash"])[idx]
    feats = np.asarray(q["feat_q"])[idx]
    out = dict(q)
    out["head"] = jnp.asarray(head + take, I32)
    return out, slots, hashes, feats


def occupancy(q: Dict) -> int:
    return int(q["tail"]) - int(q["head"])
