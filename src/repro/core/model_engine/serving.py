"""Serving-model factory: train, quantize, checkpoint, and build the
Model Engine's DNN from one ``FenixConfig(model=...)`` name.

This closes the paper's model loop (§6 "Model Training and Quantization"
-> §5.2 "DNN Inference Module"): the float traffic classifier
(models/traffic.py) is trained on trace-ingested flows (the PR-4 pcap/CSV
adapters; ``synthetic_corpus`` writes a deterministic pcap fixture and
reads it back through the real ingest path, so CI trains through the same
code a real ISCXVPN2016/USTC-TFC download would), post-training-quantized
to the INT8 fixed-point scheme (quant/quantize.py), and wrapped in an
:class:`~repro.core.model_engine.inference.EngineModel` whose every GEMM
runs through ``kernels/int8_matmul`` — the serving hot path of all four
drivers.

Model names (``FenixConfig.model``):

  ``"bylen"``          the deterministic stand-in (data-plane benchmarks)
  ``"int8_cnn"``       paper-sized FENIX-CNN, trained + quantized
  ``"int8_rnn"``       paper-sized FENIX-RNN, trained + quantized
  ``"int8_cnn_tiny"``  CI-sized CNN (same structure, shrunk; tests)
  ``"int8_rnn_tiny"``  CI-sized RNN

Quantized checkpoints: :func:`save_quantized` / :func:`load_quantized`
persist the integer model (int8 weights + per-layer shifts + model config)
through the atomic train/checkpoint.py layout, and
``FenixConfig(model_dir=...)`` serves straight from one — training on
real corpora happens once, offline (docs/TRAINING.md).  Without a
``model_dir`` the factory trains a default instance on the synthetic
fixture corpus and caches it per process, so every driver in a test
session serves the *same* quantized weights (the cross-driver conformance
suite depends on this).
"""

from __future__ import annotations

import dataclasses
import functools
import os
import tempfile
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.fenix_models import (MODEL_CONFIGS, TrafficModelConfig,
                                        model_config)
from repro.core.model_engine.inference import ByLenModel, EngineModel
from repro.data.synthetic_traffic import (Flow, class_weights, make_flows,
                                          task_meta, windows_from_flows)
from repro.models import traffic
from repro.quant.quantize import quantize_traffic
from repro.train import checkpoint as ckpt_lib

SERVING_MODELS = ("bylen",) + tuple(sorted(MODEL_CONFIGS))

# CI-sized defaults for the in-process trained model (docs/TRAINING.md
# shows the real-corpus settings; these exist to keep the tier-1 suite
# and the benchmark smokes inside their time budgets)
DEFAULT_TASK = "iscx"
DEFAULT_FLOWS = 240
DEFAULT_STEPS = 120
DEFAULT_SEED = 11


def synthetic_corpus(task: str = DEFAULT_TASK, n_flows: int = DEFAULT_FLOWS,
                     seed: int = DEFAULT_SEED,
                     pcap_path: Optional[str] = None) -> List[Flow]:
    """Deterministic stand-in corpus, routed through the real ingest path.

    Synthesizes class-conditioned flows, writes them as actual pcap bytes
    plus the ground-truth sidecar (``trace_ingest.synthesize_pcap``), and
    reads them back with ``trace_ingest.load_flows`` — the same adapter
    stack a downloaded ISCXVPN2016/USTC-TFC capture goes through, so the
    training loop exercises ingestion end-to-end even in CI.  ``pcap_path``
    keeps the fixture (e.g. ``benchmarks/fixtures``); None uses a temp file.
    """
    from repro.data.trace_ingest import load_flows, synthesize_pcap

    flows = make_flows(task, n_flows, seed=seed, min_per_class=12)
    if pcap_path is None:
        with tempfile.TemporaryDirectory() as td:
            p = os.path.join(td, f"{task}_corpus.pcap")
            synthesize_pcap(flows, p)
            return load_flows(p)
    synthesize_pcap(flows, pcap_path)
    return load_flows(pcap_path)


def train_quantized(mcfg: TrafficModelConfig, flows: List[Flow],
                    steps: int = DEFAULT_STEPS, seed: int = 0,
                    batch: int = 256, lr: float = 3e-3,
                    ckpt_dir: Optional[str] = None,
                    calib: int = 512) -> Tuple[Dict, Dict, Dict]:
    """Float-train on flow windows, then post-training-quantize to INT8.

    Returns ``(params, qparams, metrics)``: the float weights, the integer
    model (int8 weights/LUTs + per-layer shifts — everything
    ``int8_apply`` needs), and the final training metrics.  ``ckpt_dir``
    threads through to the fault-tolerant trainer (auto-resume, NaN
    recovery); the first ``calib`` training windows calibrate the
    activation grids.
    """
    from repro.train.optimizer import OptConfig
    from repro.train.trainer import Trainer, TrainerConfig, batch_iterator

    x, y, _ = windows_from_flows(flows, seed=seed)
    w = class_weights(y, mcfg.num_classes)
    params = traffic.init(mcfg, seed=seed)
    trainer = Trainer(
        lambda p, b: traffic.loss_fn(p, mcfg, b), params,
        TrainerConfig(total_steps=steps, log_every=10**9,
                      ckpt_dir=ckpt_dir,
                      opt=OptConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                                    total_steps=steps, weight_decay=0.01)))
    metrics = trainer.run(batch_iterator(x, y, batch, seed=seed, weights=w))
    qp = quantize_traffic(trainer.params, mcfg, jnp.asarray(x[:calib]))
    return trainer.params, qp, metrics


# -- quantized checkpoints ---------------------------------------------------

def save_quantized(model_dir: str, qp: Dict, mcfg: TrafficModelConfig,
                   meta: Optional[Dict] = None) -> str:
    """Persist the integer model: one atomic checkpoint step holding the
    quantized params plus the model config (restored by
    :func:`load_quantized` / served by ``FenixConfig(model_dir=...)``)."""
    m = {"model_config": dataclasses.asdict(mcfg), **(meta or {})}
    return ckpt_lib.save(model_dir, 0, {"qparams": qp}, meta=m)


def load_quantized(model_dir: str) -> Tuple[Dict, TrafficModelConfig]:
    """Inverse of :func:`save_quantized` -> (qparams, model config)."""
    restored = ckpt_lib.restore_latest(model_dir)
    if restored is None:
        raise FileNotFoundError(
            f"no quantized checkpoint under {model_dir!r} "
            f"(expected a serving.save_quantized layout)")
    state, meta = restored
    mc = dict(meta["model_config"])
    mc["conv_filters"] = tuple(mc["conv_filters"])
    mc["fc_dims"] = tuple(mc["fc_dims"])
    return state["qparams"], TrafficModelConfig(**mc)


# -- the FenixConfig(model=...) factory --------------------------------------

@functools.lru_cache(maxsize=None)
def _default_trained(name: str, task: str
                     ) -> Tuple[TrafficModelConfig, Dict]:
    """Train-and-quantize the default instance of a named model, once per
    process.  Cached so every FenixSystem in a session (all four drivers
    of the conformance suite) serves identical quantized weights."""
    mcfg = model_config(name, num_classes=len(task_meta(task)[0]))
    flows = synthetic_corpus(task)
    _, qp, _ = train_quantized(mcfg, flows, seed=DEFAULT_SEED)
    return mcfg, qp


def build_model(name: str, matmul_backend: Optional[str] = None,
                model_dir: Optional[str] = None, task: str = DEFAULT_TASK):
    """Resolve ``FenixConfig(model=, matmul_backend=, model_dir=)`` to a
    serving model instance.

    ``"bylen"`` returns the deterministic stand-in (and rejects a
    ``matmul_backend``, which would silently do nothing).  The int8 names
    load a quantized checkpoint from ``model_dir`` when given, else the
    process-cached default trained on the synthetic fixture corpus; the
    resulting :class:`EngineModel` dispatches every GEMM through
    ``kernels/int8_matmul`` on the chosen backend.
    """
    if name == "bylen":
        if matmul_backend is not None:
            raise ValueError(
                "matmul_backend selects the int8 GEMM backend; model "
                "'bylen' runs no GEMMs — pick an int8_* model or drop "
                "the knob")
        return ByLenModel()
    if name not in MODEL_CONFIGS:
        raise ValueError(f"unknown model {name!r}; expected one of "
                         f"{SERVING_MODELS}")
    if matmul_backend is not None:
        from repro.kernels.int8_matmul.ops import validate_backend
        validate_backend(matmul_backend)
    if model_dir is not None:
        qp, mcfg = load_quantized(model_dir)
    else:
        mcfg, qp = _default_trained(name, task)
    return EngineModel(mcfg, qp, backend=matmul_backend or "ref")


def evaluate_quantized(qp: Dict, mcfg: TrafficModelConfig,
                       x: np.ndarray, y: np.ndarray,
                       backend: str = "ref") -> Dict:
    """Window-level eval of an integer model: macro-F1 + confusion.

    The verification half of the >90% claim: the confusion matrix shows
    whether the F1 rides one majority class (benchmarks/bench_accuracy).
    """
    from repro.baselines.common import confusion_matrix, macro_f1
    from repro.quant.quantize import int8_apply

    pred = np.asarray(jnp.argmax(
        int8_apply(qp, mcfg, jnp.asarray(x), backend=backend), -1))
    return {"macro_f1": macro_f1(y, pred, mcfg.num_classes),
            "confusion": confusion_matrix(y, pred,
                                          mcfg.num_classes).tolist(),
            "pred": pred}
