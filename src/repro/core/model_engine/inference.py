"""DNN Inference Module (§5.2): INT8 CNN/RNN on the systolic GEMM.

Executes the quantized traffic model (quant/quantize.py) over feature
batches; every matmul/conv maps onto kernels/int8_matmul — the same
"one systolic array, many layer types" structure as the FPGA.  A simple
cycle model provides the latency/throughput numbers for the Figure 11
microbenchmark: MACs / (array_width^2 * f_clk) plus a fixed pipeline fill.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.fenix_models import TrafficModelConfig
from repro.quant.quantize import int8_apply


@dataclasses.dataclass(frozen=True)
class EngineModel:
    """A quantized traffic model serving on the INT8 systolic GEMM.

    ``qparams`` is the integer model from ``quant.quantize_traffic`` (or a
    ``serving.load_quantized`` checkpoint); ``backend`` selects the
    ``kernels/int8_matmul`` implementation for every GEMM this model runs
    — one of ``ops.MATMUL_BACKENDS``, threaded from
    ``FenixConfig(matmul_backend=...)`` by the serving factory.
    """

    cfg: TrafficModelConfig
    qparams: Dict
    backend: str = "ref"         # "ref" (CPU sim) | "pallas" | "pallas_tpu"

    @property
    def num_classes(self) -> int:
        return self.cfg.num_classes

    def infer(self, payload: jax.Array) -> jax.Array:
        """payload [B, T, 2] int32 -> class [B] int32."""
        logits = int8_apply(self.qparams, self.cfg, payload,
                            backend=self.backend)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def infer_engines(self, payload: jax.Array) -> jax.Array:
        """payload [E, B, T, 2] int32 -> class [E, B] int32.

        Batched farm inference: each engine's service batch runs through
        the same quantized model in one fused pass (classes are per-lane,
        so batching across engines cannot change any verdict).  Used when
        a single host drives several engines' lanes at once — the sharded
        farm step instead calls ``infer`` per engine shard.
        """
        e, b = payload.shape[:2]
        flat = payload.reshape((e * b,) + payload.shape[2:])
        return self.infer(flat).reshape(e, b)


class ByLenModel:
    """Deterministic stand-in Model Engine: class = F9 pkt_len mod 7.

    The one shared copy for benchmarks/examples/tests that measure the
    data plane and drivers rather than DNN quality — cross-driver
    identity assertions must compare against the *same* model, so do not
    redeclare this locally.
    """

    num_classes = 7

    def infer(self, payload: jax.Array) -> jax.Array:
        return (payload[:, -1, 0] % self.num_classes).astype(jnp.int32)

    def infer_engines(self, payload: jax.Array) -> jax.Array:
        return (payload[:, :, -1, 0] % self.num_classes).astype(jnp.int32)


def macs_per_inference(cfg: TrafficModelConfig) -> int:
    """Multiply-accumulates for one feature window (cycle model input)."""
    e = cfg.embed_dim
    d_in = 2 * e
    t = cfg.seq_len
    total = 0
    if cfg.kind == "cnn":
        c_prev = d_in
        for ch in cfg.conv_filters:
            total += t * cfg.conv_kernel * c_prev * ch
            c_prev = ch
        f_prev = c_prev
        for fc in cfg.fc_dims:
            total += f_prev * fc
            f_prev = fc
        total += f_prev * cfg.num_classes
    else:
        u = cfg.rnn_units
        total += t * (d_in * u + u * u)
        total += u * cfg.num_classes
    return total


@dataclasses.dataclass(frozen=True)
class CycleModel:
    """ZU19EG-style array: width x width INT8 MACs at f_clk."""
    array_width: int = 32
    f_clk_hz: float = 300e6
    pipeline_fill_cycles: int = 64

    def latency_us(self, cfg: TrafficModelConfig) -> float:
        macs = macs_per_inference(cfg)
        cycles = macs / (self.array_width ** 2) + self.pipeline_fill_cycles
        return cycles / self.f_clk_hz * 1e6

    def throughput_inf_per_s(self, cfg: TrafficModelConfig) -> float:
        macs = macs_per_inference(cfg)
        return self.f_clk_hz * self.array_width ** 2 / macs

    # -- engine-farm accounting (E independent arrays, ISSUE 3) -------------
    def farm_throughput_inf_per_s(self, cfg: TrafficModelConfig,
                                  num_engines: int) -> float:
        """Aggregate service rate of ``num_engines`` independent engines.

        Engines drain their ingress queues independently (no cross-engine
        pipeline), so farm throughput is additive.
        """
        return num_engines * self.throughput_inf_per_s(cfg)

    def farm_batch_latency_us(self, cfg: TrafficModelConfig, batch: int,
                              num_engines: int) -> float:
        """Service latency of a ``batch`` split across ``num_engines``.

        The router balances the batch (ceil split); each engine pipelines
        its share through its own systolic array: one fill + latency for
        the first inference, then one result per ``macs / width^2`` cycles.
        ``num_engines=1`` degenerates to the single-engine batch latency.
        """
        per_engine = -(-batch // max(num_engines, 1))
        if per_engine <= 0:
            return 0.0
        macs = macs_per_inference(cfg)
        issue_us = macs / (self.array_width ** 2) / self.f_clk_hz * 1e6
        return self.latency_us(cfg) + (per_engine - 1) * issue_us


def tpu_latency_us(cfg: TrafficModelConfig, batch: int = 128) -> Dict:
    """Roofline latency of the same window batch on one TPU v5e MXU.

    compute = MACs*2 / 197 TFLOP/s (int8 runs at >= bf16 peak); memory =
    weight+activation bytes / 819 GB/s.  Reported in the Fig. 11 analogue.
    """
    macs = macs_per_inference(cfg) * batch
    flops = 2.0 * macs
    w_bytes = macs_per_inference(cfg)  # int8: ~1 byte per unique MAC weight
    t_compute = flops / 197e12 * 1e6
    t_memory = (w_bytes + batch * cfg.seq_len * 2 * 4) / 819e9 * 1e6
    return {"compute_us": t_compute, "memory_us": t_memory,
            "latency_us": max(t_compute, t_memory)}
