"""Leo [NSDI'24] baseline: online decision tree at line rate.

Per the paper's §7.1(g): a decision tree (deep, up to 1024 leaf nodes)
on packet-length extremes and cumulative flow length, evaluated per packet
from switch register state.  We fit a complete-tree CART of depth 10
(= 1024 leaves) on the same prefix features Leo uses.
"""

from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.baselines.common import flow_feature_matrix
from repro.core.data_engine.decision_tree import (TreeParams, fit_tree,
                                                  predict, tree_arrays)
from repro.data.synthetic_traffic import Flow

# feature indices used by Leo: min_len, max_len, cum_len, pkt_cnt
_LEO_FEATS = (0, 1, 3, 4)
_DEPTH = 10


class LeoModel:
    def __init__(self, num_classes: int):
        self.num_classes = num_classes
        self.tree: TreeParams = None
        self.arrs: Dict = None

    def fit(self, flows: List[Flow], positions=(1, 3, 7, 15, 31)) -> None:
        x, y, _ = flow_feature_matrix(flows, positions)
        x = x[:, _LEO_FEATS].astype(np.int64)
        self.tree = fit_tree(x, y, depth=_DEPTH,
                             num_classes=self.num_classes)
        self.arrs = tree_arrays(self.tree)

    def predict_packets(self, flows: List[Flow], positions=(1, 3, 7, 15, 31)
                        ) -> Dict[str, np.ndarray]:
        xs, ys, fs = flow_feature_matrix(flows, positions)
        x = jnp.asarray(xs[:, _LEO_FEATS].astype(np.int32))
        pred = np.asarray(predict(self.arrs, x, _DEPTH))
        return {"pred": pred, "label": ys, "flow": fs}
