"""FlowLens [NDSS'21] baseline: flow markers on the switch + control-plane
gradient-boosted trees.

Per §7.1(c): the switch accumulates per-flow "flow marker" histograms
(packet-size and inter-packet-delay bin counts); the control plane runs an
XGBoost-style classifier on the collected markers.  Flow-level only, with
millisecond collection+inference latency (the Figure 11 comparison).

The booster here is a compact multiclass GBDT (softmax objective, depth-3
regression trees, shrinkage 0.3) — numpy-only, no external deps.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.data.synthetic_traffic import Flow

_LEN_BINS = np.array([64, 128, 256, 512, 768, 1024, 1280, 1500])
_IPD_BINS = np.array([100, 1000, 10_000, 100_000, 1_000_000])


def flow_marker(flow: Flow, max_pkts: int = 64) -> np.ndarray:
    """FlowLens FMA: truncated histograms of sizes and IPDs."""
    ln = flow.pkt_len[:max_pkts]
    ipd = flow.ipd_us[1:max_pkts]
    h1 = np.histogram(ln, bins=np.concatenate([[0], _LEN_BINS]))[0]
    h2 = np.histogram(ipd, bins=np.concatenate([[0], _IPD_BINS]))[0]
    return np.concatenate([h1, h2, [len(ln)]]).astype(np.float64)


def markers(flows: List[Flow]) -> Tuple[np.ndarray, np.ndarray]:
    x = np.stack([flow_marker(f) for f in flows])
    y = np.asarray([f.label for f in flows], np.int32)
    return x, y


# ---------------------------------------------------------------------------
# Tiny multiclass GBDT
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _RegTree:
    feature: np.ndarray
    threshold: np.ndarray
    value: np.ndarray      # leaf values

    def predict(self, x: np.ndarray) -> np.ndarray:
        node = np.zeros(len(x), np.int64)
        n_internal = len(self.feature)
        depth = int(np.log2(n_internal + 1))
        for _ in range(depth):
            f = self.feature[node]
            t = self.threshold[node]
            node = 2 * node + 1 + (x[np.arange(len(x)), f] >= t)
        return self.value[node - n_internal]


def _fit_reg_tree(x: np.ndarray, g: np.ndarray, depth: int = 3,
                  rng=None) -> _RegTree:
    """Fit residuals g with a complete variance-reduction tree."""
    n_internal = (1 << depth) - 1
    feat = np.zeros(n_internal, np.int64)
    thr = np.zeros(n_internal, np.float64)
    value = np.zeros(1 << depth, np.float64)
    sets = {0: np.arange(len(g))}
    for node in range(n_internal):
        idx = sets.get(node, np.array([], np.int64))
        best = (np.inf, 0, 0.0)
        if len(idx) > 4:
            for f in range(x.shape[1]):
                vals = x[idx, f]
                cand = np.unique(np.percentile(vals, [25, 50, 75]))
                for t in cand:
                    right = vals >= t
                    if right.all() or (~right).all():
                        continue
                    sse = g[idx[right]].var() * right.sum() \
                        + g[idx[~right]].var() * (~right).sum()
                    if sse < best[0]:
                        best = (sse, f, float(t))
        feat[node], thr[node] = best[1], best[2]
        if len(idx):
            right = x[idx, best[1]] >= best[2]
            sets[2 * node + 1] = idx[~right]
            sets[2 * node + 2] = idx[right]
    first = n_internal
    for leaf in range(1 << depth):
        idx = sets.get(first + leaf, np.array([], np.int64))
        value[leaf] = g[idx].mean() if len(idx) else 0.0
    return _RegTree(feat, thr, value)


class FlowLensModel:
    def __init__(self, num_classes: int, rounds: int = 25, lr: float = 0.3,
                 depth: int = 3):
        self.k = num_classes
        self.rounds = rounds
        self.lr = lr
        self.depth = depth
        self.trees: List[List[_RegTree]] = []

    def fit(self, x: np.ndarray, y: np.ndarray) -> None:
        n = len(y)
        fmat = np.zeros((n, self.k))
        onehot = np.eye(self.k)[y]
        for _ in range(self.rounds):
            p = np.exp(fmat - fmat.max(1, keepdims=True))
            p /= p.sum(1, keepdims=True)
            grads = onehot - p                     # negative gradient
            round_trees = []
            for c in range(self.k):
                t = _fit_reg_tree(x, grads[:, c], depth=self.depth)
                fmat[:, c] += self.lr * t.predict(x)
                round_trees.append(t)
            self.trees.append(round_trees)

    def predict(self, x: np.ndarray) -> np.ndarray:
        fmat = np.zeros((len(x), self.k))
        for round_trees in self.trees:
            for c, t in enumerate(round_trees):
                fmat[:, c] += self.lr * t.predict(x)
        return fmat.argmax(1).astype(np.int32)
