"""NetBeacon [USENIX Sec'23] baseline: multi-phase tree models in the
switch.

Per §7.1(f): each phase is a Random Forest (3 trees, depth 7) evaluated at
a packet-count checkpoint with flow-level register features; predictions
update only at phase boundaries (the paper's noted limitation for
fine-grained per-packet tasks).
"""

from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.baselines.common import flow_feature_matrix
from repro.core.data_engine.decision_tree import (fit_tree, predict,
                                                  tree_arrays)
from repro.data.synthetic_traffic import Flow

_DEPTH = 7
_N_TREES = 3
_PHASES = (3, 7, 15)


class NetBeaconModel:
    def __init__(self, num_classes: int, seed: int = 0):
        self.num_classes = num_classes
        self.seed = seed
        self.phase_forests: List[List[Dict]] = []

    def fit(self, flows: List[Flow]) -> None:
        rng = np.random.default_rng(self.seed)
        self.phase_forests = []
        for p in _PHASES:
            x, y, _ = flow_feature_matrix(flows, positions=(p,))
            x = x.astype(np.int64)
            forest = []
            for t in range(_N_TREES):
                idx = rng.integers(0, len(y), len(y))   # bootstrap
                tree = fit_tree(x[idx], y[idx], depth=_DEPTH,
                                num_classes=self.num_classes)
                forest.append(tree_arrays(tree))
            self.phase_forests.append(forest)

    def _forest_predict(self, forest, x: np.ndarray) -> np.ndarray:
        votes = np.stack([np.asarray(predict(t, jnp.asarray(
            x.astype(np.int32)), _DEPTH)) for t in forest])
        out = np.empty(x.shape[0], np.int32)
        for i in range(x.shape[0]):
            out[i] = np.bincount(votes[:, i],
                                 minlength=self.num_classes).argmax()
        return out

    def predict_packets(self, flows: List[Flow]) -> Dict[str, np.ndarray]:
        """Per-checkpoint predictions (phase verdict holds until the next)."""
        preds, labels, fids = [], [], []
        for pi, p in enumerate(_PHASES):
            x, y, f = flow_feature_matrix(flows, positions=(p,))
            pr = self._forest_predict(self.phase_forests[pi], x)
            preds.append(pr)
            labels.append(y)
            fids.append(f)
        return {"pred": np.concatenate(preds),
                "label": np.concatenate(labels),
                "flow": np.concatenate(fids)}
