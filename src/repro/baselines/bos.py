"""BoS [NSDI'24] baseline: binarized GRU on the switch.

Per §7.1(h): the largest BoS variant — binarized GRU weights (+-1 via
straight-through estimator), 6-bit embeddings, 9-bit fixed-point hidden
states, 8 GRU units, embedding->GRU->output structure.  The binarization
and the tiny hidden width are exactly what costs BoS accuracy vs FENIX's
full-precision-trained INT8 models (Table 2 analysis).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.fenix_models import TrafficModelConfig
from repro.models import traffic
from repro.models.param import Registrar

F32 = jnp.float32
_UNITS = 8
_EMB_BITS = 6
_HID_BITS = 9


def _binarize_ste(w: jax.Array) -> jax.Array:
    """sign(w) with straight-through gradient."""
    return w + jax.lax.stop_gradient(jnp.sign(w) - w)


def _quant_ste(x: jax.Array, bits: int, amax: float) -> jax.Array:
    scale = (2 ** (bits - 1) - 1) / amax
    q = jnp.clip(jnp.round(x * scale), -(2 ** (bits - 1) - 1),
                 2 ** (bits - 1) - 1) / scale
    return x + jax.lax.stop_gradient(q - x)


def init(cfg: TrafficModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    reg = Registrar(abstract=False, seed=seed, dtype=F32)
    e = cfg.embed_dim
    reg.param("embed_len/table", (cfg.len_buckets, e), ("vocab", "embed"),
              scale=0.5, dtype=F32)
    reg.param("embed_ipd/table", (cfg.ipd_buckets, e), ("vocab", "embed"),
              scale=0.5, dtype=F32)
    d_in = 2 * e
    for nm, shape in (("wz", (d_in + _UNITS, _UNITS)),
                      ("wr", (d_in + _UNITS, _UNITS)),
                      ("wh", (d_in + _UNITS, _UNITS))):
        reg.param(f"gru/{nm}", shape, ("embed", "ffn"),
                  scale=shape[0] ** -0.5, dtype=F32)
    reg.param("head/w", (_UNITS, cfg.num_classes), ("embed", "classes"),
              scale=_UNITS ** -0.5, dtype=F32)
    reg.param("head/b", (cfg.num_classes,), ("classes",), init="zeros",
              dtype=F32)
    return reg.params


def apply(params: Dict, cfg: TrafficModelConfig,
          payload: jax.Array) -> jax.Array:
    ids = traffic.bucketize(payload, cfg)
    el = jnp.take(_quant_ste(params["embed_len/table"], _EMB_BITS, 1.0),
                  ids[..., 0], axis=0)
    ei = jnp.take(_quant_ste(params["embed_ipd/table"], _EMB_BITS, 1.0),
                  ids[..., 1], axis=0)
    x = jnp.concatenate([el, ei], axis=-1)            # [B,T,2E]
    wz = _binarize_ste(params["gru/wz"])
    wr = _binarize_ste(params["gru/wr"])
    wh = _binarize_ste(params["gru/wh"])
    scale = 1.0 / np.sqrt(x.shape[-1] + _UNITS)       # keep pre-acts sane

    def cell(h, xt):
        xa = jnp.concatenate([xt, h], axis=-1)
        z = jax.nn.sigmoid(xa @ wz * scale)
        r = jax.nn.sigmoid(xa @ wr * scale)
        xa2 = jnp.concatenate([xt, r * h], axis=-1)
        hh = jnp.tanh(xa2 @ wh * scale)
        h2 = (1 - z) * h + z * hh
        h2 = _quant_ste(h2, _HID_BITS, 1.0)           # 9-bit hidden states
        return h2, None

    h0 = jnp.zeros((x.shape[0], _UNITS), x.dtype)
    h, _ = jax.lax.scan(cell, h0, x.swapaxes(0, 1))
    return h @ params["head/w"] + params["head/b"]


def loss_fn(params: Dict, cfg: TrafficModelConfig, batch: Dict
            ) -> Tuple[jax.Array, Dict]:
    logits = apply(params, cfg, batch["payload"])
    labels = batch["label"]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    w = batch.get("weight")
    loss = jnp.mean(nll * w) if w is not None else jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(F32))
    return loss, {"acc": acc}
