"""Shared feature builders + metrics for the baseline schemes (§7.1)."""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.data.synthetic_traffic import Flow


def flow_prefix_features(flow: Flow, upto: int) -> np.ndarray:
    """Per-packet flow-state features after `upto`+1 packets (switch regs):
    [min_len, max_len, mean_len, cum_len, pkt_cnt, mean_ipd, last_len]."""
    ln = flow.pkt_len[:upto + 1].astype(np.float64)
    ipd = flow.ipd_us[1:upto + 1].astype(np.float64)
    return np.asarray([
        ln.min(), ln.max(), ln.mean(), ln.sum(), len(ln),
        ipd.mean() if len(ipd) else 0.0, ln[-1]], np.float64)


def flow_feature_matrix(flows: List[Flow], positions=(3, 7, 15),
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Features at checkpoint positions: X [N,F], y [N], flow_id [N]."""
    xs, ys, fs = [], [], []
    for fi, f in enumerate(flows):
        for p in positions:
            if p < len(f.pkt_len):
                xs.append(flow_prefix_features(f, p))
                ys.append(f.label)
                fs.append(fi)
    return np.stack(xs), np.asarray(ys, np.int32), np.asarray(fs, np.int32)


def macro_f1(y_true: np.ndarray, y_pred: np.ndarray, n_classes: int) -> float:
    f1s = []
    for c in range(n_classes):
        tp = np.sum((y_pred == c) & (y_true == c))
        fp = np.sum((y_pred == c) & (y_true != c))
        fn = np.sum((y_pred != c) & (y_true == c))
        prec = tp / max(tp + fp, 1)
        rec = tp / max(tp + fn, 1)
        f1s.append(2 * prec * rec / max(prec + rec, 1e-9))
    return float(np.mean(f1s))


def per_class_prf(y_true: np.ndarray, y_pred: np.ndarray, n_classes: int
                  ) -> List[Tuple[float, float]]:
    out = []
    for c in range(n_classes):
        tp = np.sum((y_pred == c) & (y_true == c))
        fp = np.sum((y_pred == c) & (y_true != c))
        fn = np.sum((y_pred != c) & (y_true == c))
        out.append((tp / max(tp + fp, 1), tp / max(tp + fn, 1)))
    return out


def confusion_matrix(y_true: np.ndarray, y_pred: np.ndarray,
                     n_classes: int) -> np.ndarray:
    """[n_classes, n_classes] counts, rows = true class, cols = predicted.

    The per-class companion of :func:`macro_f1`: a high macro-F1 riding
    one majority class shows up here as empty off-diagonal rows.
    """
    cm = np.zeros((n_classes, n_classes), np.int64)
    np.add.at(cm, (np.asarray(y_true, np.int64),
                   np.asarray(y_pred, np.int64)), 1)
    return cm


def flow_vote(pred: np.ndarray, flow_id: np.ndarray
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Majority vote of window/packet predictions per flow."""
    uf = np.unique(flow_id)
    votes = np.empty(len(uf), np.int32)
    for i, f in enumerate(uf):
        p = pred[flow_id == f]
        votes[i] = np.bincount(p).argmax()
    return uf, votes
