"""N3IC [NSDI'22] baseline: binary MLP on a SmartNIC.

Per §7.1(i): binary-weight MLP with hidden layers [128, 64, 10] over
flow-level + packet-level features.  (The paper simulates the NIC side in
software due to hardware constraints; ours is the same simulation.)
The NIC bottleneck FENIX's Fig. 1 highlights is throughput, not accuracy —
N3IC's accuracy lands between the switch-tree methods and FENIX.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.bos import _binarize_ste
from repro.baselines.common import flow_feature_matrix
from repro.data.synthetic_traffic import Flow
from repro.models.param import Registrar

F32 = jnp.float32
_HIDDEN = (128, 64, 10)


def build_features(flows: List[Flow], positions=(3, 7, 15)
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    x, y, f = flow_feature_matrix(flows, positions)
    # log-scale the magnitudes, z-score-free (NIC integer pipeline style)
    x = np.log1p(np.abs(x)).astype(np.float32)
    return x, y, f


def init(n_features: int, num_classes: int, seed: int = 0) -> Dict:
    reg = Registrar(abstract=False, seed=seed, dtype=F32)
    prev = n_features
    for i, h in enumerate(_HIDDEN):
        reg.param(f"fc{i}/w", (prev, h), ("embed", "ffn"),
                  scale=prev ** -0.5, dtype=F32)
        reg.param(f"fc{i}/b", (h,), ("ffn",), init="zeros", dtype=F32)
        prev = h
    reg.param("head/w", (prev, num_classes), ("embed", "classes"),
              scale=prev ** -0.5, dtype=F32)
    reg.param("head/b", (num_classes,), ("classes",), init="zeros",
              dtype=F32)
    return reg.params


def apply(params: Dict, x: jax.Array) -> jax.Array:
    for i in range(len(_HIDDEN)):
        w = _binarize_ste(params[f"fc{i}/w"])
        scale = 1.0 / np.sqrt(w.shape[0])
        x = jax.nn.relu(x @ w * scale + params[f"fc{i}/b"])
    return x @ params["head/w"] + params["head/b"]


def loss_fn(params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
    logits = apply(params, batch["payload"])
    labels = batch["label"]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    w = batch.get("weight")
    loss = jnp.mean(nll * w) if w is not None else jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(F32))
    return loss, {"acc": acc}
