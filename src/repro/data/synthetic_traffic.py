"""Class-conditioned synthetic network traffic (ISCXVPN2016 / USTC-TFC
analogues — the real pcap corpora are not available offline; DESIGN.md §7).

Each class is a parametric flow generator over the paper's feature modality:
packet-length sequences + inter-packet delays.  Class signatures follow the
qualitative behavior of the real applications (VoIP: small constant packets
at ~20ms cadence; Streaming: MTU bursts; Chat: small packets, long pauses;
File/P2P: sustained MTU; Web: mixed bursts...), with heavy overlap and
per-flow jitter so that sequence models (CNN/RNN) beat per-packet trees —
the ordering the paper's Table 2 demonstrates.

Class imbalance matches Table 1 (11:4:13:10:18:128:1 and
92:10:4:14:17:23:105:1:16:132:27:1); oversampling/undersampling weights are
provided for the paper's §6 imbalance mitigation.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

ISCX_CLASSES = ("chat", "email", "file", "p2p", "stream", "voip", "web")
ISCX_RATIO = (11, 4, 13, 10, 18, 128, 1)

USTC_CLASSES = ("cridex", "ftp", "geodo", "htbot", "neris", "nsis-ay",
                "warcraft", "zeus", "virut", "weibo", "shifu", "smb")
USTC_RATIO = (92, 10, 4, 14, 17, 23, 105, 1, 16, 132, 27, 1)


@dataclasses.dataclass
class ClassProfile:
    len_mean: float
    len_std: float
    len_bimodal: float      # probability of an MTU-sized packet
    ipd_log_mu: float       # log10 microseconds
    ipd_log_sigma: float
    burstiness: float       # prob of continuing a burst (tiny IPD)
    flow_len_mean: int


def _profiles(task: str) -> List[ClassProfile]:
    if task == "iscx":
        return [
            ClassProfile(120, 60, 0.02, 5.2, 0.7, 0.10, 60),    # chat
            ClassProfile(420, 180, 0.10, 4.6, 0.8, 0.25, 40),   # email
            ClassProfile(1250, 220, 0.55, 3.2, 0.6, 0.70, 220),  # file
            ClassProfile(1050, 320, 0.45, 3.5, 0.9, 0.55, 180),  # p2p
            ClassProfile(1330, 120, 0.70, 3.9, 0.4, 0.60, 300),  # stream
            ClassProfile(172, 24, 0.00, 4.3, 0.15, 0.05, 400),  # voip
            ClassProfile(640, 420, 0.25, 4.0, 1.1, 0.40, 50),   # web
        ]
    # ustc malware/benign mix: each family gets a distinct temporal
    # signature (beacon cadence, transfer bursts, chatty C2, bulk SMB...)
    base = [
        ClassProfile(140, 30, 0.02, 5.6, 0.25, 0.05, 80),   # cridex: slow beacon
        ClassProfile(1350, 150, 0.65, 3.0, 0.5, 0.75, 150),  # ftp: bulk
        ClassProfile(420, 60, 0.05, 4.9, 0.35, 0.12, 60),   # geodo: med beacon
        ClassProfile(250, 180, 0.20, 3.6, 1.3, 0.45, 100),  # htbot: erratic
        ClassProfile(90, 25, 0.01, 4.1, 0.9, 0.30, 90),     # neris: tiny spam
        ClassProfile(700, 120, 0.30, 4.4, 0.5, 0.25, 110),  # nsis-ay
        ClassProfile(190, 40, 0.00, 4.35, 0.12, 0.05, 300),  # warcraft: game tick
        ClassProfile(520, 90, 0.08, 5.1, 0.4, 0.10, 85),    # zeus: fat beacon
        ClassProfile(330, 250, 0.35, 3.3, 1.1, 0.60, 95),   # virut: bursty mix
        ClassProfile(980, 280, 0.45, 3.8, 0.8, 0.50, 70),   # weibo: media
        ClassProfile(620, 70, 0.12, 4.65, 0.2, 0.08, 75),   # shifu: regular mid
        ClassProfile(1180, 220, 0.55, 3.45, 0.4, 0.65, 130),  # smb: bulk lan
    ]
    return base


def task_meta(task: str) -> Tuple[Tuple[str, ...], Tuple[int, ...]]:
    if task == "iscx":
        return ISCX_CLASSES, ISCX_RATIO
    if task == "ustc":
        return USTC_CLASSES, USTC_RATIO
    raise ValueError(task)


@dataclasses.dataclass
class Flow:
    label: int
    five_tuple: Tuple[int, int, int, int, int]
    start_us: int
    pkt_len: np.ndarray       # [n] int32
    ipd_us: np.ndarray        # [n] int32 (ipd[0] = 0)

    @property
    def ts_us(self) -> np.ndarray:
        return (self.start_us + np.cumsum(self.ipd_us)).astype(np.int64)


def make_flows(task: str, n_flows: int, seed: int = 0,
               duration_s: float = 60.0,
               min_per_class: int = 0) -> List[Flow]:
    """min_per_class stratifies rare classes (the paper's 100k-flow corpora
    have >=200 flows even for ratio-1 classes; small synthetic runs need the
    floor to make macro-F1 measurable)."""
    rng = np.random.default_rng(seed)
    classes, ratio = task_meta(task)
    profs = _profiles(task)
    probs = np.asarray(ratio, np.float64) / sum(ratio)
    labels = rng.choice(len(classes), size=n_flows, p=probs)
    if min_per_class:
        counts = np.bincount(labels, minlength=len(classes))
        fix = []
        for c in range(len(classes)):
            fix += [c] * max(min_per_class - counts[c], 0)
        if fix:
            idx = rng.choice(n_flows, len(fix), replace=False)
            labels[idx] = np.asarray(fix)
    flows: List[Flow] = []
    for i, lab in enumerate(labels):
        p = profs[lab]
        n = max(10, int(rng.gamma(3.0, p.flow_len_mean / 3.0)))
        n = min(n, 2000)
        # per-flow jitter: shift the whole flow's signature
        lm = p.len_mean * rng.uniform(0.8, 1.25)
        im = p.ipd_log_mu + rng.normal(0, 0.25)
        mtu = rng.random(n) < p.len_bimodal
        lens = np.where(
            mtu, 1500 - rng.integers(0, 60, n),
            np.clip(rng.normal(lm, p.len_std, n), 40, 1500))
        in_burst = rng.random(n) < p.burstiness
        ipd = np.where(
            in_burst,
            rng.integers(20, 400, n),
            (10.0 ** rng.normal(im, p.ipd_log_sigma, n))).astype(np.int64)
        ipd = np.clip(ipd, 10, 5_000_000)
        ipd[0] = 0
        start = int(rng.uniform(0, duration_s * 1e6 * 0.5))
        ft = (int(rng.integers(1, 2**31)), int(rng.integers(1, 2**31)),
              int(rng.integers(1024, 65535)), int(rng.integers(1, 1024)),
              6 if rng.random() < 0.8 else 17)
        flows.append(Flow(int(lab), ft, start,
                          lens.astype(np.int32), ipd.astype(np.int32)))
    return flows


def uniform_flow_stream(n_pkts: int, n_flows: int, seed: int = 0,
                        gap_us: int = 10) -> Dict[str, np.ndarray]:
    """Interleaved multi-packet flows at a fixed aggregate rate.

    A structureless load generator (vs the class-conditioned ``make_flows``
    path): ``n_flows`` random persistent 5-tuples with per-flow-constant
    packet lengths, arrivals uniform at ``1e6 / gap_us`` offered pps.
    Flows persist, so the flow table, backlog counters, and probability
    gate see realistic per-flow state.  Used by the engine-farm benchmarks
    and CI smokes; includes ``flow_idx`` for per-flow assertions.
    """
    rng = np.random.default_rng(seed)
    five = {k: rng.integers(1, 2**31, n_flows).astype(np.uint32)
            for k in ("src_ip", "dst_ip")}
    five["src_port"] = rng.integers(1, 65536, n_flows).astype(np.uint32)
    five["dst_port"] = rng.integers(1, 65536, n_flows).astype(np.uint32)
    five["proto"] = rng.integers(6, 18, n_flows).astype(np.uint32)
    lens = (40 + rng.integers(0, 1400, n_flows)).astype(np.int32)
    fidx = rng.integers(0, n_flows, n_pkts).astype(np.int32)
    stream = {k: v[fidx] for k, v in five.items()}
    stream["pkt_len"] = lens[fidx]
    stream["ts_us"] = np.sort(
        rng.integers(0, n_pkts * gap_us, n_pkts)).astype(np.int32)
    stream["flow_idx"] = fidx
    return stream


def ring_window(feats: np.ndarray, end: int, win: int) -> np.ndarray:
    """Window ENDING at packet `end` inclusive, front-padded with zeros —
    exactly what the switch ring buffer holds when packet `end` arrives."""
    lo = max(0, end + 1 - win)
    w = feats[lo:end + 1]
    if len(w) < win:
        w = np.concatenate([np.zeros((win - len(w), feats.shape[1]),
                                     feats.dtype), w])
    return w


def oracle_payloads(oracle: List[np.ndarray], flow_idx: np.ndarray,
                    flow_pos: np.ndarray, win: int) -> np.ndarray:
    """Ground-truth ring window for EVERY packet of a stream, vectorized.

    ``oracle[f]`` is flow f's [n_f, feat_dim] feature sequence; packet i of
    the stream gets ``ring_window(oracle[flow_idx[i]], flow_pos[i], win)``.
    Returns [n, win, feat_dim] int32 — the device trace driver gathers
    granted packets' windows from this array instead of re-deriving them
    per batch on the host.
    """
    from numpy.lib.stride_tricks import sliding_window_view

    flow_idx = np.asarray(flow_idx)
    flow_pos = np.asarray(flow_pos)
    feat_dim = oracle[0].shape[1] if len(oracle) else 2
    out = np.zeros((len(flow_idx), win, feat_dim), np.int32)
    for fi in np.unique(flow_idx):
        feats = np.asarray(oracle[int(fi)], np.int32)
        padded = np.concatenate(
            [np.zeros((win - 1, feats.shape[1]), np.int32), feats])
        sw = sliding_window_view(padded, win, axis=0)   # [n_f, feat, win]
        mask = flow_idx == fi
        out[mask] = np.transpose(sw[flow_pos[mask]], (0, 2, 1))
    return out


def windows_from_flows(flows: List[Flow], win: int = 9,
                       stride: int = 4, max_windows_per_flow: int = 16,
                       seed: int = 0
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Ring-aligned sliding windows (paper §6): payload [N, win, 2].

    Windows end at sampled packet positions and are front-padded, matching
    the deployed Buffer-Manager semantics (F1..F8 history + F9 current).
    """
    rng = np.random.default_rng(seed)
    ps, ls, fs = [], [], []
    for fi, f in enumerate(flows):
        feats = np.stack([f.pkt_len, f.ipd_us], axis=-1)   # [n,2]
        n = len(f.pkt_len)
        ends = list(range(1, n, stride))
        if len(ends) > max_windows_per_flow:
            ends = list(rng.choice(ends, max_windows_per_flow,
                                   replace=False))
        for e in ends:
            ps.append(ring_window(feats, e, win))
            ls.append(f.label)
            fs.append(fi)
    return (np.stack(ps).astype(np.int32), np.asarray(ls, np.int32),
            np.asarray(fs, np.int32))


def class_weights(labels: np.ndarray, n_classes: int) -> np.ndarray:
    """Inverse-frequency weights (the paper's over/under-sampling, §6)."""
    cnt = np.bincount(labels, minlength=n_classes).astype(np.float64)
    w = np.where(cnt > 0, len(labels) / (n_classes * np.maximum(cnt, 1)), 0.0)
    return w[labels]


def packet_stream(flows: List[Flow], limit: Optional[int] = None
                  ) -> Dict[str, np.ndarray]:
    """Interleave flows into one time-ordered packet stream (Data Engine)."""
    recs = []
    for fi, f in enumerate(flows):
        ts = f.ts_us
        for j in range(len(f.pkt_len)):
            recs.append((ts[j], fi, f.pkt_len[j]))
    recs.sort()
    if limit:
        recs = recs[:limit]
    n = len(recs)
    out = {
        "ts_us": np.empty(n, np.int32), "pkt_len": np.empty(n, np.int32),
        "src_ip": np.empty(n, np.uint32), "dst_ip": np.empty(n, np.uint32),
        "src_port": np.empty(n, np.uint32),
        "dst_port": np.empty(n, np.uint32),
        "proto": np.empty(n, np.uint32),
        "flow_idx": np.empty(n, np.int32),
        "flow_pos": np.empty(n, np.int32),
        "label": np.empty(n, np.int32),
    }
    pos_ctr: Dict[int, int] = {}
    for i, (ts, fi, ln) in enumerate(recs):
        f = flows[fi]
        out["ts_us"][i] = ts % (2**31 - 1)
        out["pkt_len"][i] = ln
        out["src_ip"][i], out["dst_ip"][i] = f.five_tuple[0], f.five_tuple[1]
        out["src_port"][i], out["dst_port"][i] = (f.five_tuple[2],
                                                  f.five_tuple[3])
        out["proto"][i] = f.five_tuple[4]
        out["flow_idx"][i] = fi
        out["flow_pos"][i] = pos_ctr.get(fi, 0)
        pos_ctr[fi] = out["flow_pos"][i] + 1
        out["label"][i] = f.label
    return out


def train_test_split(x, y, f, test_frac: float = 0.2, seed: int = 0):
    """Split BY FLOW (no window leakage between train and test)."""
    rng = np.random.default_rng(seed)
    flow_ids = np.unique(f)
    rng.shuffle(flow_ids)
    n_test = max(1, int(len(flow_ids) * test_frac))
    test_flows = set(flow_ids[:n_test].tolist())
    mask = np.asarray([fi in test_flows for fi in f])
    return (x[~mask], y[~mask], f[~mask]), (x[mask], y[mask], f[mask])
