"""Per-dataset schema adapters: real trace exports -> the repro's ``Flow``s.

The paper evaluates on ISCXVPN2016 and USTC-TFC (Table 1/2); both corpora
ship as raw pcaps plus flow-level CSV exports (CICFlowMeter-style for ISCX,
flow summaries for USTC).  This module normalizes those CSV layouts — and a
generic packet-level 5-tuple CSV — into the exact
:class:`repro.data.synthetic_traffic.Flow` objects the rest of the repo
consumes, with labels mapped onto ``ISCX_CLASSES`` / ``USTC_CLASSES``.

Raw pcap parsing lives in :mod:`repro.data.trace_ingest`; this module owns
everything schema-shaped: column aliasing, label vocabularies, IP/proto/
timestamp coercion, and the deterministic flow-level -> packet-level
reconstruction (flow rows only carry aggregates, so packets are laid out
evenly across the reported duration/byte budget — no randomness, so runs
are reproducible).
"""

from __future__ import annotations

import csv
import dataclasses
import datetime
import io
import os
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.data.synthetic_traffic import Flow, ISCX_CLASSES, USTC_CLASSES


class TraceFormatError(ValueError):
    """Malformed trace input: bad pcap magic, truncated record, unknown
    CSV column or label — always with a message saying what was expected."""


# ---------------------------------------------------------------------------
# field coercion helpers
# ---------------------------------------------------------------------------

_PROTO_NAMES = {"tcp": 6, "udp": 17, "icmp": 1, "igmp": 2, "gre": 47,
                "esp": 50, "sctp": 132}


def parse_ip(raw: Union[str, int]) -> int:
    """Dotted-quad or plain-integer IPv4 address -> uint32 host int."""
    if isinstance(raw, (int, np.integer)):
        return int(raw) & 0xFFFFFFFF
    s = str(raw).strip()
    if "." in s:
        parts = s.split(".")
        if len(parts) != 4:
            raise TraceFormatError(f"bad IPv4 address {raw!r}")
        try:
            octets = [int(p) for p in parts]
        except ValueError as e:
            raise TraceFormatError(f"bad IPv4 address {raw!r}") from e
        if any(o < 0 or o > 255 for o in octets):
            raise TraceFormatError(f"bad IPv4 address {raw!r}")
        return (octets[0] << 24) | (octets[1] << 16) \
            | (octets[2] << 8) | octets[3]
    try:
        return int(float(s)) & 0xFFFFFFFF
    except ValueError as e:
        raise TraceFormatError(f"bad IPv4 address {raw!r}") from e


def parse_proto(raw: Union[str, int]) -> int:
    """IANA protocol number or name ("tcp"/"udp"/...) -> int."""
    if isinstance(raw, (int, np.integer)):
        return int(raw)
    s = str(raw).strip().lower()
    if s in _PROTO_NAMES:
        return _PROTO_NAMES[s]
    try:
        return int(float(s))
    except ValueError as e:
        raise TraceFormatError(
            f"bad protocol {raw!r} (want a number or one of "
            f"{sorted(_PROTO_NAMES)})") from e


def parse_time_us(raw: Union[str, float, int], unit_us: float) -> int:
    """Numeric timestamp (x ``unit_us`` -> microseconds) or ISO datetime."""
    if isinstance(raw, (int, float, np.integer, np.floating)):
        return int(round(float(raw) * unit_us))
    s = str(raw).strip()
    try:
        return int(round(float(s) * unit_us))
    except ValueError:
        pass
    try:
        dt = datetime.datetime.fromisoformat(s)
    except ValueError as e:
        raise TraceFormatError(
            f"bad timestamp {raw!r} (want a number or ISO datetime)") from e
    if dt.tzinfo is None:
        dt = dt.replace(tzinfo=datetime.timezone.utc)
    return int(round(dt.timestamp() * 1e6))


def _norm(name: str) -> str:
    """Normalize a CSV header / label for matching: lower-case, spaces and
    underscores folded to single dashes."""
    out = "".join(c if c.isalnum() else "-" for c in str(name).lower())
    while "--" in out:
        out = out.replace("--", "-")
    return out.strip("-")


# ---------------------------------------------------------------------------
# schemas
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CsvSchema:
    """One dataset's CSV layout.

    ``level`` is "packet" (one row per packet) or "flow" (one row per flow,
    aggregates only).  ``columns`` maps canonical field names to accepted
    header spellings (matched after :func:`_norm`).  ``label_aliases`` maps
    normalized raw labels to canonical class names from ``classes``.
    """

    name: str
    level: str
    classes: Tuple[str, ...]
    columns: Mapping[str, Tuple[str, ...]]
    label_aliases: Mapping[str, str]
    time_unit_us: float = 1.0       # timestamps column -> microseconds
    duration_unit_us: float = 1.0   # duration column -> microseconds


_GENERIC_COLUMNS = {
    "ts": ("ts-us", "ts", "timestamp", "time"),
    "src_ip": ("src-ip", "source-ip", "saddr", "ip-src"),
    "dst_ip": ("dst-ip", "destination-ip", "daddr", "ip-dst"),
    "src_port": ("src-port", "source-port", "sport"),
    "dst_port": ("dst-port", "destination-port", "dport"),
    "proto": ("proto", "protocol"),
    "pkt_len": ("pkt-len", "packet-length", "length", "len", "frame-len"),
    "label": ("label", "class", "app"),
    "flow_id": ("flow-id", "flow-idx", "flow"),
}

_ISCX_ALIASES = {
    "chat": "chat", "aim": "chat", "icq": "chat", "facebook-chat": "chat",
    "hangouts-chat": "chat", "skype-chat": "chat",
    "email": "email", "smtp": "email", "pop3": "email", "imap": "email",
    "gmail": "email",
    "file": "file", "file-transfer": "file", "ft": "file", "ftps": "file",
    "sftp": "file", "scp": "file", "skype-file": "file",
    "p2p": "p2p", "torrent": "p2p", "bittorrent": "p2p", "utorrent": "p2p",
    "stream": "stream", "streaming": "stream", "youtube": "stream",
    "netflix": "stream", "vimeo": "stream", "spotify": "stream",
    "voip": "voip", "skype-audio": "voip", "voipbuster": "voip",
    "hangouts-audio": "voip",
    "web": "web", "browsing": "web", "http": "web", "https": "web",
}

_USTC_ALIASES = {
    "cridex": "cridex",
    "ftp": "ftp",
    "geodo": "geodo", "emotet": "geodo",
    "htbot": "htbot",
    "neris": "neris",
    "nsis-ay": "nsis-ay", "nsis": "nsis-ay",
    "warcraft": "warcraft", "world-of-warcraft": "warcraft",
    "wow": "warcraft",
    "zeus": "zeus",
    "virut": "virut",
    "weibo": "weibo",
    "shifu": "shifu",
    "smb": "smb",
}

GENERIC = CsvSchema(
    name="generic",
    level="packet",
    classes=ISCX_CLASSES,
    columns=_GENERIC_COLUMNS,
    label_aliases=_ISCX_ALIASES,
)

ISCX_VPN = CsvSchema(
    name="iscx_vpn",
    level="flow",
    classes=ISCX_CLASSES,
    columns={
        "src_ip": ("src-ip", "source-ip"),
        "src_port": ("src-port", "source-port"),
        "dst_ip": ("dst-ip", "destination-ip"),
        "dst_port": ("dst-port", "destination-port"),
        "proto": ("protocol", "proto"),
        "start": ("timestamp", "flow-start-time", "start"),
        "duration": ("flow-duration", "duration"),
        "packets": ("total-fwd-packets", "tot-fwd-pkts", "total-packets",
                    "packets"),
        "bytes": ("total-length-of-fwd-packets", "totlen-fwd-pkts",
                  "total-bytes", "bytes"),
        "label": ("label", "class"),
    },
    label_aliases=_ISCX_ALIASES,
    time_unit_us=1e6,       # CICFlowMeter timestamps are in seconds
    duration_unit_us=1.0,   # Flow Duration is already microseconds
)

USTC_TFC = CsvSchema(
    name="ustc_tfc",
    level="flow",
    classes=USTC_CLASSES,
    columns={
        "src_ip": ("src-ip", "sa", "srcip"),
        "src_port": ("sport", "src-port"),
        "dst_ip": ("dst-ip", "da", "dstip"),
        "dst_port": ("dport", "dst-port"),
        "proto": ("protocol", "proto"),
        "start": ("first-seen", "start-time", "ts"),
        "duration": ("duration-ms", "duration"),
        "packets": ("pkt-count", "packets", "num-pkts"),
        "bytes": ("byte-count", "bytes"),
        "label": ("app", "label", "family"),
    },
    label_aliases=_USTC_ALIASES,
    time_unit_us=1e3,       # first_seen in milliseconds
    duration_unit_us=1e3,   # duration in milliseconds
)

ADAPTERS: Dict[str, CsvSchema] = {
    "generic": GENERIC,
    "iscx_vpn": ISCX_VPN,
    "ustc_tfc": USTC_TFC,
}


def get_adapter(name: Union[str, CsvSchema]) -> CsvSchema:
    if isinstance(name, CsvSchema):
        return name
    try:
        return ADAPTERS[name]
    except KeyError:
        raise TraceFormatError(
            f"unknown trace adapter {name!r}; valid adapters: "
            f"{', '.join(sorted(ADAPTERS))}") from None


def map_label(raw: Union[str, int], schema: CsvSchema,
              strict: bool = True) -> int:
    """Raw dataset label -> class index in ``schema.classes``.

    Accepts numeric class indices, canonical class names, any alias in
    ``schema.label_aliases``, and "vpn-" prefixed variants of either.
    Unknown labels raise :class:`TraceFormatError` (or return -1 when
    ``strict`` is false).
    """
    if isinstance(raw, (int, np.integer)) or \
            (isinstance(raw, str) and raw.strip().lstrip("-").isdigit()):
        # numeric labels are already class indices (dataset-encoded);
        # range-checking them against a task is the caller's business
        idx = int(raw)
        if idx >= -1:
            return idx
        if not strict:
            return -1
        raise TraceFormatError(
            f"bad numeric label {idx} for {schema.name} (want >= -1)")
    key = _norm(raw)
    for k in (key, key[4:] if key.startswith("vpn-") else key):
        name = schema.label_aliases.get(k, k)
        if name in schema.classes:
            return schema.classes.index(name)
    if not strict:
        return -1
    raise TraceFormatError(
        f"unknown {schema.name} label {raw!r}; known labels: "
        f"{', '.join(sorted(set(schema.label_aliases)))}")


# ---------------------------------------------------------------------------
# CSV -> flows
# ---------------------------------------------------------------------------


def _resolve_columns(schema: CsvSchema, fieldnames: Sequence[str],
                     required: Sequence[str]) -> Dict[str, str]:
    have = {_norm(h): h for h in fieldnames if h is not None}
    out: Dict[str, str] = {}
    for field, candidates in schema.columns.items():
        for cand in candidates:
            if cand in have:
                out[field] = have[cand]
                break
    missing = [f for f in required if f not in out]
    if missing:
        raise TraceFormatError(
            f"{schema.name} CSV is missing column(s) {missing}; "
            f"have: {sorted(have)}")
    return out


def _five_tuple(row: Mapping[str, str],
                cols: Mapping[str, str]) -> Tuple[int, int, int, int, int]:
    return (parse_ip(row[cols["src_ip"]]), parse_ip(row[cols["dst_ip"]]),
            int(float(row[cols["src_port"]])),
            int(float(row[cols["dst_port"]])),
            parse_proto(row[cols["proto"]]))


def _flow_from_aggregates(ft: Tuple[int, int, int, int, int], label: int,
                          start_us: int, duration_us: int, n_pkts: int,
                          n_bytes: int) -> Flow:
    """Deterministic packet layout for a flow-level row: ``n_pkts`` packets
    spread evenly over ``duration_us`` carrying ``n_bytes`` total (lengths
    clipped to the feature pipeline's [40, 1500] plausible-IP range)."""
    n = max(1, int(n_pkts))
    base, rem = divmod(max(int(n_bytes), 0), n)
    lens = np.full(n, base, np.int64)
    lens[:rem] += 1
    lens = np.clip(lens, 40, 1500).astype(np.int32)
    ipd = np.zeros(n, np.int64)
    if n > 1:
        step, irem = divmod(max(int(duration_us), 0), n - 1)
        ipd[1:] = step
        ipd[1:1 + irem] += 1
    ipd = np.clip(ipd, 0, 2**31 - 1).astype(np.int32)
    return Flow(label=int(label), five_tuple=ft, start_us=int(start_us),
                pkt_len=lens, ipd_us=ipd)


def _open_text(source):
    if hasattr(source, "read"):
        return source, False
    return open(os.fspath(source), "r", newline=""), True


def flows_from_csv(source, schema: Union[str, CsvSchema] = "generic",
                   strict_labels: bool = True,
                   max_flows: Optional[int] = None) -> List[Flow]:
    """Parse a CSV export into ``Flow`` objects via a schema adapter.

    Packet-level schemas group rows into flows by the ``flow_id`` column
    when present, else by 5-tuple (first-seen order); flow-level schemas
    reconstruct a deterministic packet sequence from each row's aggregate
    packet/byte/duration columns.
    """
    schema = get_adapter(schema)
    f, should_close = _open_text(source)
    try:
        reader = csv.DictReader(f)
        if not reader.fieldnames:
            raise TraceFormatError(f"{schema.name} CSV is empty (no header)")
        if schema.level == "flow":
            return _read_flow_level(reader, schema, strict_labels, max_flows)
        return _read_packet_level(reader, schema, strict_labels, max_flows)
    finally:
        if should_close:
            f.close()


def _read_flow_level(reader, schema, strict_labels, max_flows):
    required = ("src_ip", "dst_ip", "src_port", "dst_port", "proto",
                "start", "duration", "packets", "bytes")
    cols = _resolve_columns(schema, reader.fieldnames, required)
    flows: List[Flow] = []
    for row in reader:
        if max_flows is not None and len(flows) >= max_flows:
            break
        label = -1
        if "label" in cols and row.get(cols["label"]) not in (None, ""):
            label = map_label(row[cols["label"]], schema,
                              strict=strict_labels)
        flows.append(_flow_from_aggregates(
            _five_tuple(row, cols), label,
            parse_time_us(row[cols["start"]], schema.time_unit_us),
            int(round(float(row[cols["duration"]])
                      * schema.duration_unit_us)),
            int(float(row[cols["packets"]])),
            int(float(row[cols["bytes"]]))))
    return flows


def _read_packet_level(reader, schema, strict_labels, max_flows):
    required = ("ts", "src_ip", "dst_ip", "src_port", "dst_port", "proto",
                "pkt_len")
    cols = _resolve_columns(schema, reader.fieldnames, required)
    by_flow: Dict[object, Dict] = {}
    for row in reader:
        ft = _five_tuple(row, cols)
        if "flow_id" in cols and row.get(cols["flow_id"]) not in (None, ""):
            key: object = int(float(row[cols["flow_id"]]))
        else:
            key = ft
        rec = by_flow.get(key)
        if rec is None:
            if max_flows is not None and len(by_flow) >= max_flows:
                continue
            rec = by_flow[key] = {"ft": ft, "ts": [], "len": [],
                                  "label": -1}
        rec["ts"].append(parse_time_us(row[cols["ts"]],
                                       schema.time_unit_us))
        rec["len"].append(int(float(row[cols["pkt_len"]])))
        if rec["label"] < 0 and "label" in cols and \
                row.get(cols["label"]) not in (None, ""):
            rec["label"] = map_label(row[cols["label"]], schema,
                                     strict=strict_labels)
    flows: List[Flow] = []
    keys = sorted(by_flow) if all(
        isinstance(k, int) for k in by_flow) else list(by_flow)
    for key in keys:
        rec = by_flow[key]
        order = np.argsort(np.asarray(rec["ts"], np.int64), kind="stable")
        ts = np.asarray(rec["ts"], np.int64)[order]
        lens = np.asarray(rec["len"], np.int64)[order]
        ipd = np.zeros(len(ts), np.int64)
        ipd[1:] = np.diff(ts)
        flows.append(Flow(
            label=int(rec["label"]), five_tuple=rec["ft"],
            start_us=int(ts[0]),
            pkt_len=lens.astype(np.int32),
            ipd_us=np.clip(ipd, 0, 2**31 - 1).astype(np.int32)))
    return flows


def flows_from_csv_text(text: str, schema: Union[str, CsvSchema] = "generic",
                        **kw) -> List[Flow]:
    """Convenience wrapper: parse CSV content given as a string."""
    return flows_from_csv(io.StringIO(text), schema, **kw)
