"""Streaming trace ingestion: raw pcap / CSV -> the drivers' packet_stream.

Hand-rolled parser for the classic libpcap capture format (24-byte global
header + 16-byte per-record headers; both byte orders, microsecond and
nanosecond magics) — no libpcap/scapy dependency.  Frames are decoded as
Ethernet (or raw-IP linktype) -> IPv4 -> TCP/UDP ports, and normalized into
the exact column dict ``repro.data.synthetic_traffic.packet_stream``
produces: the data-plane keys consumed by every driver
(``ts_us/pkt_len/src_ip/dst_ip/src_port/dst_port/proto``) plus the flow
bookkeeping the oracle paths use (``flow_idx/flow_pos/label``).

The reader is chunked: records are decoded ``chunk_pkts`` at a time, so a
multi-GB capture never materializes in host memory — only the fixed-size
column arrays of the packets actually kept (``limit=``) do.

``synthesize_pcap`` is the inverse: it writes a synthetic flow set out as
real pcap bytes (plus a per-flow label sidecar CSV, the stand-in for the
datasets' ground-truth files).  It doubles as the CI fixture generator and
the correctness oracle: ``pcap -> ingest -> packet_stream`` must equal the
original synthetic stream bit-for-bit (asserted in
tests/test_trace_ingest.py and re-checked on every CI cache hit by
examples/trace_smoke.py).
"""

from __future__ import annotations

import csv
import dataclasses
import os
import struct
from typing import Dict, Iterator, List, Mapping, Optional, Tuple, Union

import numpy as np

from repro.data import trace_formats as tf
from repro.data.synthetic_traffic import Flow, packet_stream
from repro.data.trace_formats import TraceFormatError

PCAP_MAGIC_US = 0xA1B2C3D4
PCAP_MAGIC_NS = 0xA1B23C4D
LINKTYPE_ETHERNET = 1
LINKTYPE_RAW = 101          # raw IPv4/IPv6, no link-layer header
ETHERTYPE_IPV4 = 0x0800

# column dtypes of the packet_stream dict (bit-identity contract)
STREAM_DTYPES = {
    "ts_us": np.int32, "pkt_len": np.int32,
    "src_ip": np.uint32, "dst_ip": np.uint32,
    "src_port": np.uint32, "dst_port": np.uint32, "proto": np.uint32,
    "flow_idx": np.int32, "flow_pos": np.int32, "label": np.int32,
}
PKT_COLS = ("ts_us", "pkt_len", "src_ip", "dst_ip", "src_port",
            "dst_port", "proto")

_TS_MOD = 2**31 - 1         # packet_stream's int32 timestamp wrap


def _open_binary(source):
    if hasattr(source, "read"):
        return source, False
    return open(os.fspath(source), "rb"), True


def _parse_global_header(hdr: bytes) -> Tuple[str, bool, int, int]:
    """-> (endianness, nanosecond?, snaplen, linktype)."""
    if len(hdr) == 0:
        raise TraceFormatError("empty pcap: no global header")
    if len(hdr) < 24:
        raise TraceFormatError(
            f"truncated pcap global header: got {len(hdr)} of 24 bytes")
    for endian in ("<", ">"):
        magic = struct.unpack(endian + "I", hdr[:4])[0]
        if magic in (PCAP_MAGIC_US, PCAP_MAGIC_NS):
            _vmaj, _vmin, _tz, _sig, snaplen, network = struct.unpack(
                endian + "HHiIII", hdr[4:24])
            return endian, magic == PCAP_MAGIC_NS, snaplen, network
    raise TraceFormatError(
        f"bad pcap magic 0x{struct.unpack('<I', hdr[:4])[0]:08x} "
        f"(expected 0x{PCAP_MAGIC_US:08x} or 0x{PCAP_MAGIC_NS:08x}, "
        f"either byte order)")


def _parse_frame(body: bytes, linktype: int):
    """One captured frame -> (pkt_len, src, dst, sport, dport, proto),
    or None for non-IPv4 frames (counted as skipped by the caller)."""
    if linktype == LINKTYPE_ETHERNET:
        if len(body) < 14:
            return None
        if body[12] != (ETHERTYPE_IPV4 >> 8) or \
                body[13] != (ETHERTYPE_IPV4 & 0xFF):
            return None
        ip = body[14:]
    else:                               # LINKTYPE_RAW
        ip = body
    if len(ip) < 20 or (ip[0] >> 4) != 4:
        return None
    ihl = (ip[0] & 0x0F) * 4
    if ihl < 20:
        return None
    total_len = (ip[2] << 8) | ip[3]
    proto = ip[9]
    src = int.from_bytes(ip[12:16], "big")
    dst = int.from_bytes(ip[16:20], "big")
    sport = dport = 0
    if proto in (6, 17) and len(ip) >= ihl + 4:
        sport = (ip[ihl] << 8) | ip[ihl + 1]
        dport = (ip[ihl + 2] << 8) | ip[ihl + 3]
    return total_len, src, dst, sport, dport, proto


def iter_pcap_packets(source, chunk_pkts: int = 65536,
                      stats: Optional[Dict[str, int]] = None
                      ) -> Iterator[Dict[str, np.ndarray]]:
    """Stream a pcap as column-array chunks of the 7 data-plane keys.

    Yields dicts with :data:`PKT_COLS` arrays of up to ``chunk_pkts``
    packets each; the file is read incrementally, so captures far larger
    than host memory stream through.  Timestamps are rebased to the first
    record when they exceed the int32 microsecond range (real epoch-stamped
    captures) and wrapped mod 2^31-1, exactly like ``packet_stream``;
    synthetic fixtures (already int32) pass through untouched.  Non-IPv4
    frames are skipped and counted in ``stats["skipped"]``.
    """
    if stats is None:
        stats = {}
    stats.setdefault("skipped", 0)
    f, should_close = _open_binary(source)
    try:
        endian, nanos, _snaplen, linktype = _parse_global_header(f.read(24))
        if linktype not in (LINKTYPE_ETHERNET, LINKTYPE_RAW):
            raise TraceFormatError(
                f"unsupported pcap linktype {linktype} (want "
                f"{LINKTYPE_ETHERNET}=Ethernet or {LINKTYPE_RAW}=raw IP)")
        rec_hdr = struct.Struct(endian + "IIII")
        offset = 24
        ts_base: Optional[int] = None
        cols: List[List[int]] = [[] for _ in PKT_COLS]

        def _flush():
            out = {k: np.asarray(c, dtype=STREAM_DTYPES[k])
                   for k, c in zip(PKT_COLS, cols)}
            for c in cols:
                c.clear()
            return out

        while True:
            rh = f.read(16)
            if not rh:
                break
            if len(rh) < 16:
                raise TraceFormatError(
                    f"truncated pcap record header at offset {offset}: "
                    f"got {len(rh)} of 16 bytes")
            sec, frac, incl, _orig = rec_hdr.unpack(rh)
            offset += 16
            body = f.read(incl)
            if len(body) < incl:
                raise TraceFormatError(
                    f"truncated pcap record body at offset {offset}: "
                    f"expected {incl} bytes, got {len(body)}")
            offset += incl
            ts_us = sec * 1_000_000 + (frac // 1000 if nanos else frac)
            if ts_base is None:
                # epoch-stamped captures rebase to their first record so
                # timestamps fit the drivers' int32 microsecond clock;
                # synthetic fixtures (already < 2^31-1) pass through
                ts_base = ts_us if ts_us > _TS_MOD else 0
            parsed = _parse_frame(body, linktype)
            if parsed is None:
                stats["skipped"] += 1
                continue
            cols[0].append((ts_us - ts_base) % _TS_MOD)
            for col, v in zip(cols[1:], parsed):
                col.append(v)
            if len(cols[0]) >= chunk_pkts:
                yield _flush()
        if cols[0]:
            yield _flush()
    finally:
        if should_close:
            f.close()


# ---------------------------------------------------------------------------
# flow bookkeeping (flow_idx / flow_pos / label)
# ---------------------------------------------------------------------------


class _FlowTable:
    """First-seen flow numbering + per-flow packet positions, carried
    across chunks.  A labels sidecar pre-assigns (flow_id, label) per
    5-tuple — ids from the sidecar are authoritative, so ingesting a
    ``synthesize_pcap`` fixture reproduces the source stream's ``flow_idx``
    exactly; unseen 5-tuples get fresh ids after the sidecar's range."""

    def __init__(self, sidecar: Optional[Mapping] = None):
        self.ids: Dict[Tuple, int] = {}
        self.labels: Dict[int, int] = {}
        self.pos: Dict[int, int] = {}
        self.next_id = 0
        if sidecar:
            for ft_key, (fid, label) in sidecar.items():
                self.ids[ft_key] = fid
                self.labels[fid] = label
            self.next_id = max(self.labels) + 1

    def assign(self, chunk: Dict[str, np.ndarray]
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        if len(chunk["ts_us"]) == 0:
            z = np.zeros(0, np.int32)
            return z, z.copy(), z.copy()
        keys = np.stack([chunk[k].astype(np.int64) for k in
                         ("src_ip", "dst_ip", "src_port", "dst_port",
                          "proto")], axis=1)
        uniq, first, inv = np.unique(keys, axis=0, return_index=True,
                                     return_inverse=True)
        inv = inv.reshape(-1)
        fid_of_uniq = np.empty(len(uniq), np.int64)
        # visit uniques in first-seen order so id assignment is invariant
        # to chunk size (np.unique itself sorts lexicographically)
        for u in np.argsort(first, kind="stable"):
            key = tuple(int(x) for x in uniq[u])
            fid = self.ids.get(key)
            if fid is None:
                fid = self.ids[key] = self.next_id
                self.labels.setdefault(fid, -1)
                self.next_id += 1
            fid_of_uniq[u] = fid
        fids = fid_of_uniq[inv]
        # running per-flow packet position: rank within the chunk (stable
        # grouping) + the base carried from earlier chunks
        order = np.argsort(inv, kind="stable")
        ranks = np.empty(len(inv), np.int64)
        grouped = inv[order]
        starts = np.concatenate([[0], np.flatnonzero(
            np.diff(grouped)) + 1]) if len(inv) else np.zeros(0, np.int64)
        ranks[order] = np.arange(len(inv)) - np.repeat(
            starts, np.diff(np.concatenate([starts, [len(inv)]])))
        base = np.asarray([self.pos.get(int(fid), 0)
                           for fid in fid_of_uniq], np.int64)
        pos = ranks + base[inv]
        counts = np.bincount(inv, minlength=len(uniq))
        for u, fid in enumerate(fid_of_uniq):
            self.pos[int(fid)] = int(base[u] + counts[u])
        labels = np.asarray([self.labels.get(int(fid), -1)
                             for fid in fid_of_uniq], np.int64)[inv]
        return (fids.astype(np.int32), pos.astype(np.int32),
                labels.astype(np.int32))


def read_flow_labels(source) -> Dict[Tuple, Tuple[int, int]]:
    """Read a per-flow ground-truth sidecar CSV:
    ``flow_id,src_ip,dst_ip,src_port,dst_port,proto,label`` ->
    {5-tuple: (flow_id, label)}."""
    f, should_close = (source, False) if hasattr(source, "read") else \
        (open(os.fspath(source), "r", newline=""), True)
    try:
        out: Dict[Tuple, Tuple[int, int]] = {}
        for row in csv.DictReader(f):
            key = (tf.parse_ip(row["src_ip"]), tf.parse_ip(row["dst_ip"]),
                   int(row["src_port"]), int(row["dst_port"]),
                   tf.parse_proto(row["proto"]))
            out[key] = (int(row["flow_id"]), int(row["label"]))
        return out
    finally:
        if should_close:
            f.close()


def write_flow_labels(flows: List[Flow], path) -> None:
    """Write the ground-truth sidecar ``synthesize_pcap`` pairs with its
    capture (one row per flow, ids = positions in ``flows``)."""
    with open(os.fspath(path), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["flow_id", "src_ip", "dst_ip", "src_port", "dst_port",
                    "proto", "label"])
        for i, fl in enumerate(flows):
            w.writerow([i] + [int(x) for x in fl.five_tuple]
                       + [int(fl.label)])


def sidecar_path(pcap_path) -> str:
    """Conventional location of a capture's label sidecar."""
    return os.fspath(pcap_path) + ".labels.csv"


# ---------------------------------------------------------------------------
# whole-capture ingestion
# ---------------------------------------------------------------------------


def ingest_pcap(source, labels: Union[None, str, Mapping] = "auto",
                limit: Optional[int] = None, chunk_pkts: int = 65536,
                stats: Optional[Dict[str, int]] = None
                ) -> Dict[str, np.ndarray]:
    """pcap -> full packet_stream dict (all 10 columns).

    ``labels``: a sidecar CSV path, a pre-read mapping, ``"auto"`` (use
    ``<pcap>.labels.csv`` when present), or None.  Without a sidecar, flows
    are numbered in first-seen order and labeled -1.  ``limit`` truncates
    after that many packets without reading the rest of the capture.
    """
    if labels == "auto":
        cand = sidecar_path(source) if not hasattr(source, "read") else None
        labels = cand if cand and os.path.exists(cand) else None
    if isinstance(labels, (str, os.PathLike)):
        labels = read_flow_labels(labels)
    table = _FlowTable(labels)
    parts: List[Dict[str, np.ndarray]] = []
    kept = 0
    for chunk in iter_pcap_packets(source, chunk_pkts=chunk_pkts,
                                   stats=stats):
        if limit is not None and kept + len(chunk["ts_us"]) > limit:
            chunk = {k: v[:limit - kept] for k, v in chunk.items()}
        fid, pos, lab = table.assign(chunk)
        chunk["flow_idx"], chunk["flow_pos"] = fid, pos
        chunk["label"] = lab
        parts.append(chunk)
        kept += len(chunk["ts_us"])
        if limit is not None and kept >= limit:
            break
    if not parts:
        return {k: np.zeros(0, dt) for k, dt in STREAM_DTYPES.items()}
    return {k: np.concatenate([p[k] for p in parts])
            for k in STREAM_DTYPES}


def flows_from_stream(stream: Dict[str, np.ndarray]) -> List[Flow]:
    """Regroup a packet_stream into per-flow ``Flow`` objects (the layout
    ``windows_from_flows`` / the baselines train on).

    One global sort on (flow_idx, flow_pos) then contiguous splits —
    O(n log n), so corpus-scale captures (100k flows, millions of
    packets) regroup in one pass instead of one full scan per flow.
    """
    fids = np.asarray(stream["flow_idx"], np.int64)
    pos = np.asarray(stream["flow_pos"], np.int64)
    order = np.lexsort((pos, fids))
    fids_s = fids[order]
    ts_s = np.asarray(stream["ts_us"], np.int64)[order]
    len_s = np.asarray(stream["pkt_len"])[order]
    starts = np.concatenate([[0], np.flatnonzero(np.diff(fids_s)) + 1,
                             [len(fids_s)]]) if len(fids_s) else \
        np.zeros(1, np.int64)
    flows: List[Flow] = []
    for lo, hi in zip(starts[:-1], starts[1:]):
        ts = ts_s[lo:hi]
        ipd = np.zeros(hi - lo, np.int64)
        ipd[1:] = np.diff(ts)
        i = order[lo]
        ft = tuple(int(stream[k][i]) for k in
                   ("src_ip", "dst_ip", "src_port", "dst_port", "proto"))
        flows.append(Flow(
            label=int(stream["label"][i]), five_tuple=ft,
            start_us=int(ts[0]),
            pkt_len=len_s[lo:hi].astype(np.int32),
            ipd_us=np.clip(ipd, 0, 2**31 - 1).astype(np.int32)))
    return flows


# ---------------------------------------------------------------------------
# pcap writing / fixture synthesis
# ---------------------------------------------------------------------------


def _ip_checksum(hdr: bytes) -> int:
    s = sum(int.from_bytes(hdr[i:i + 2], "big")
            for i in range(0, len(hdr), 2))
    while s >> 16:
        s = (s & 0xFFFF) + (s >> 16)
    return (~s) & 0xFFFF


def write_pcap(stream: Dict[str, np.ndarray], path, nanos: bool = False,
               byteorder: str = "<") -> int:
    """Write a packet_stream out as classic pcap (Ethernet/IPv4/TCP|UDP).

    Only headers are materialized per packet; the IP total-length field
    carries ``pkt_len`` and the record's orig_len is the full frame size,
    so ingestion recovers the stream exactly (snaplen-truncated captures,
    like tcpdump -s).  Protocols other than TCP/UDP are written without an
    L4 header (their ports cannot survive a real capture).  Returns the
    number of records written.
    """
    magic = PCAP_MAGIC_NS if nanos else PCAP_MAGIC_US
    rec_hdr = struct.Struct(byteorder + "IIII")
    eth = b"\x02\x00\x00\x00\x00\x01\x02\x00\x00\x00\x00\x02\x08\x00"
    n = len(stream["ts_us"])
    frac_mul = 1000 if nanos else 1
    buf: List[bytes] = []
    with open(os.fspath(path), "wb") as f:
        f.write(struct.pack(byteorder + "IHHiIII", magic, 2, 4, 0, 0, 96,
                            LINKTYPE_ETHERNET))
        for i in range(n):
            proto = int(stream["proto"][i])
            sport, dport = int(stream["src_port"][i]), \
                int(stream["dst_port"][i])
            if proto == 6:
                l4 = struct.pack(">HHIIBBHHH", sport, dport, 0, 0, 5 << 4,
                                 0x10, 8192, 0, 0)
            elif proto == 17:
                pkt_len = int(stream["pkt_len"][i])
                l4 = struct.pack(">HHHH", sport, dport,
                                 max(pkt_len - 20, 8) & 0xFFFF, 0)
            else:
                l4 = b""
            total_len = int(stream["pkt_len"][i]) & 0xFFFF
            ip = struct.pack(">BBHHHBBH4s4s", 0x45, 0, total_len,
                             i & 0xFFFF, 0, 64, proto, 0,
                             int(stream["src_ip"][i]).to_bytes(4, "big"),
                             int(stream["dst_ip"][i]).to_bytes(4, "big"))
            ip = ip[:10] + _ip_checksum(ip).to_bytes(2, "big") + ip[12:]
            frame = eth + ip + l4
            ts = int(stream["ts_us"][i])
            orig = 14 + max(total_len, len(frame) - 14)
            buf.append(rec_hdr.pack(ts // 1_000_000,
                                    (ts % 1_000_000) * frac_mul,
                                    len(frame), orig))
            buf.append(frame)
            if len(buf) >= 8192:
                f.write(b"".join(buf))
                buf.clear()
        f.write(b"".join(buf))
    return n


def synthesize_pcap(flows: List[Flow], pcap_path,
                    labels_path: Union[None, str, os.PathLike] = "auto",
                    limit: Optional[int] = None,
                    nanos: bool = False) -> Dict[str, np.ndarray]:
    """Write synthetic flows out as real pcap bytes + a label sidecar.

    Deterministic: the same flows always produce the same file (IP ids are
    sequence numbers, no randomness), which is what lets CI cache fixtures
    keyed on a source hash.  Returns the interleaved source stream — the
    oracle that ``ingest_pcap(pcap_path)`` must reproduce bit-for-bit.
    """
    seen: Dict[Tuple, int] = {}
    for i, fl in enumerate(flows):
        key = tuple(int(x) for x in fl.five_tuple)
        if key[4] not in (6, 17) and (key[2] or key[3]):
            # the wire format cannot carry ports without an L4 header, so
            # ingest could never match this flow against the sidecar —
            # reject now instead of silently corrupting flow_idx/label
            raise TraceFormatError(
                f"flow {i} has protocol {key[4]} with nonzero ports "
                f"{key[2]}/{key[3]}; a pcap only carries ports for "
                f"TCP(6)/UDP(17) — zero them or switch protocol")
        if key in seen:
            raise TraceFormatError(
                f"flows {seen[key]} and {i} share 5-tuple {key}; a pcap "
                f"cannot distinguish them — regenerate with another seed")
        seen[key] = i
    stream = packet_stream(flows, limit=limit)
    write_pcap(stream, pcap_path, nanos=nanos)
    if labels_path == "auto":
        labels_path = sidecar_path(pcap_path)
    if labels_path is not None:
        write_flow_labels(flows, labels_path)
    return stream


def write_generic_csv(stream: Dict[str, np.ndarray], path) -> None:
    """Write a packet_stream as a generic packet-level 5-tuple CSV (the
    ``generic`` adapter's layout, with flow_id + numeric label columns)."""
    with open(os.fspath(path), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["ts_us", "src_ip", "dst_ip", "src_port", "dst_port",
                    "proto", "pkt_len", "label", "flow_id"])
        for i in range(len(stream["ts_us"])):
            w.writerow([int(stream[k][i]) for k in
                        ("ts_us", "src_ip", "dst_ip", "src_port",
                         "dst_port", "proto", "pkt_len", "label",
                         "flow_idx")])


# ---------------------------------------------------------------------------
# front door: path -> stream / flows
# ---------------------------------------------------------------------------


def _looks_like_pcap(path) -> bool:
    p = os.fspath(path)
    if p.endswith((".pcap", ".cap", ".dump")):
        return True
    if p.endswith(".csv"):
        return False
    try:
        with open(p, "rb") as f:
            head = f.read(4)
    except OSError:
        return False
    # both magics, either byte order
    return len(head) == 4 and struct.unpack("<I", head)[0] in (
        0xA1B2C3D4, 0xA1B23C4D, 0xD4C3B2A1, 0x4D3CB2A1)


def load_stream(source, adapter: Union[None, str, tf.CsvSchema] = None,
                labels: Union[None, str, Mapping] = "auto",
                limit: Optional[int] = None,
                chunk_pkts: int = 65536) -> Dict[str, np.ndarray]:
    """One-call trace loader: capture path (pcap or CSV) -> packet_stream.

    This is the ``source=`` selector the drivers and benchmarks thread
    through: pcaps go through the streaming record parser (with an optional
    ground-truth sidecar), CSVs through the ``adapter`` schema (default
    ``generic``) and ``packet_stream`` interleaving.  A dict passes through
    untouched so call sites can accept either form.
    """
    if isinstance(source, dict):
        return source
    if hasattr(source, "read") or _looks_like_pcap(source):
        # file-like sources stream straight through the pcap reader,
        # matching ingest_pcap/iter_pcap_packets
        return ingest_pcap(source, labels=labels, limit=limit,
                           chunk_pkts=chunk_pkts)
    flows = tf.flows_from_csv(source, adapter or "generic")
    return packet_stream(flows, limit=limit)


def load_flows(source, adapter: Union[None, str, tf.CsvSchema] = None,
               labels: Union[None, str, Mapping] = "auto",
               limit: Optional[int] = None) -> List[Flow]:
    """Capture path -> per-flow ``Flow`` list (for training/baselines)."""
    if hasattr(source, "read") or _looks_like_pcap(source):
        return flows_from_stream(ingest_pcap(source, labels=labels,
                                             limit=limit))
    return tf.flows_from_csv(source, adapter or "generic")


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Declarative trace handle for ``FenixSystem.run_trace(trace=...)``.

    Bundles a capture source with its ingestion options — the single
    replacement for the deprecated ``run_trace(source=, adapter=,
    trace_labels=, limit=)`` keyword pile.  ``load()`` materializes the
    full packet stream (what the host/pipes/farm drivers and training
    consume); ``iter_chunks()`` streams it in bounded column chunks,
    which is what the device driver's double-buffered ingest pipelines
    against the compiled scan.
    """
    # capture path (pcap or CSV), open binary file object, or an
    # already-parsed packet-stream dict (degenerate parse-free streaming)
    source: object
    # CSV schema name / CsvSchema (ignored for pcaps); default "generic"
    adapter: Union[None, str, "tf.CsvSchema"] = None
    # pcap ground-truth sidecar: path, mapping, "auto" (the
    # <pcap>.labels.csv convention), or None.  Only load() consumes it —
    # the data plane's 7 packet columns carry no labels.
    labels: Union[None, str, Mapping] = "auto"
    # truncate after this many packets without reading the rest
    limit: Optional[int] = None
    # packets per parsed chunk (streaming granularity and memory bound)
    chunk_pkts: int = 65536
    # let run_trace double-buffer: parse + device staging of chunk k+1 in
    # a background thread while the device scans chunk k.  False forces
    # synchronous staging (the bench_soak comparison baseline).
    overlap: bool = True

    def load(self) -> Dict[str, np.ndarray]:
        """Materialize the whole capture as one packet_stream dict."""
        return load_stream(self.source, adapter=self.adapter,
                           labels=self.labels, limit=self.limit,
                           chunk_pkts=self.chunk_pkts)

    def iter_chunks(self) -> Iterator[Dict[str, np.ndarray]]:
        """Stream the capture as column-dict chunks of at most
        ``chunk_pkts`` packets, honoring ``limit``.

        pcap sources stream incrementally (captures larger than host
        memory work); CSV and dict sources load once and slice — the
        chunking still lets the consumer overlap staging with compute.
        """
        streamable = not isinstance(self.source, dict) and (
            hasattr(self.source, "read") or _looks_like_pcap(self.source))
        if streamable:
            kept = 0
            for chunk in iter_pcap_packets(self.source,
                                           chunk_pkts=self.chunk_pkts):
                if self.limit is not None and \
                        kept + len(chunk["ts_us"]) > self.limit:
                    chunk = {k: v[:self.limit - kept]
                             for k, v in chunk.items()}
                if len(chunk["ts_us"]):
                    yield chunk
                kept += len(chunk["ts_us"])
                if self.limit is not None and kept >= self.limit:
                    return
            return
        stream = (self.source if isinstance(self.source, dict)
                  else self.load())
        n = len(stream["ts_us"])
        if self.limit is not None:
            n = min(n, self.limit)
        for lo in range(0, n, self.chunk_pkts):
            yield {k: np.asarray(v)[lo:min(lo + self.chunk_pkts, n)]
                   for k, v in stream.items()}
