"""repro: FENIX on TPU — public API surface."""

from repro.configs import SHAPES, get_config, list_archs  # noqa: F401

__version__ = "1.0.0"
