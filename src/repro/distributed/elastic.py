"""Elastic scaling: remesh a checkpoint onto a different device count.

Checkpoints are mesh-agnostic full arrays (train/checkpoint.py), so elastic
scale-up/down is: load -> build new mesh + rules -> compute new pspecs ->
device_put with the new NamedShardings.  ``plan_remesh`` also validates
divisibility and reports which logical axes fall back (the same
divisibility guard as model construction), so a scheduler can reject an
invalid target mesh before draining the old job.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import api
from repro.models.param import sharding_ctx, tree_pspecs


@dataclasses.dataclass
class RemeshPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    pspecs: Dict[str, P]
    fallbacks: list

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.mesh_shape))


def plan_remesh(cfg: ModelConfig, mesh: Mesh,
                rules: Optional[Dict] = None) -> RemeshPlan:
    """Dry-plan: pspecs + fallback report for the target mesh."""
    params, axes = api.init_params(cfg, abstract=True)
    with sharding_ctx(mesh, rules) as ctx:
        specs = tree_pspecs(params, axes, mesh)
        fallbacks = list(ctx.fallbacks)
    shape = tuple(mesh.shape[a] for a in mesh.axis_names)
    return RemeshPlan(shape, tuple(mesh.axis_names), specs, fallbacks)


def reshard_state(state: Dict[str, Any], plan: RemeshPlan,
                  mesh: Mesh) -> Dict[str, Any]:
    """Place a (host) checkpoint state onto the new mesh's shardings."""
    out = {}
    for k, v in state.items():
        spec = plan.pspecs.get(k, P())
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out


def scale_step_capacity(old_devices: int, new_devices: int,
                        global_batch: int) -> Tuple[int, int]:
    """Keep global batch fixed; recompute per-device batch + grad-accum.

    Returns (per_device_batch, accum_steps): if the new fleet cannot divide
    the global batch evenly, gradient accumulation keeps semantics stable
    (the 1000-node elastic policy: same tokens/step across scale events).
    """
    per = max(1, global_batch // new_devices)
    accum = max(1, int(np.ceil(global_batch / (per * new_devices))))
    return per, accum
