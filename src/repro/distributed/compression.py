"""INT8 gradient compression with error feedback (1000-node DP trick).

Before the data-parallel all-reduce, each gradient tensor is quantized to
int8 with a per-tensor scale; the quantization residual is carried into the
next step (error feedback), which provably preserves SGD convergence.  The
all-reduce then moves 4x fewer bytes (the §Roofline collective term of the
train cells is dominated by exactly this all-reduce).

On this CPU container the collective itself is GSPMD-inserted; the
quantize->(all-reduce)->dequantize round trip is what we implement and test
numerically here (compress_decompress), and it drops into the train step
via TrainerConfig.grad_compression.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass
class CompressedState:
    error: Dict[str, jax.Array]

    @staticmethod
    def init(params: Dict[str, Any]) -> "CompressedState":
        return CompressedState(
            error={k: jnp.zeros(v.shape, F32) for k, v in params.items()})


def quantize_grad(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_grad(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(F32) * scale


def compress_decompress(grads: Dict[str, Any], state: CompressedState
                        ) -> Tuple[Dict[str, Any], CompressedState]:
    """Error-feedback int8 round trip applied per tensor."""
    new_g, new_e = {}, {}
    for k, g in grads.items():
        g32 = g.astype(F32) + state.error[k]
        q, scale = quantize_grad(g32)
        deq = dequantize_grad(q, scale)
        new_g[k] = deq.astype(g.dtype)
        new_e[k] = g32 - deq
    return new_g, CompressedState(error=new_e)


jax.tree_util.register_pytree_node(
    CompressedState,
    lambda s: ((s.error,), None),
    lambda _, c: CompressedState(error=c[0]))
