"""Generic fault-tolerant training loop.

Used by the FENIX traffic classifiers (examples/, benchmarks/) and the
reduced LM configs; the same step function lowers unchanged onto the
production mesh (launch/train.py).  Features:

  - AdamW + cosine schedule (train/optimizer.py)
  - checkpoint/restart: atomic sharded npz, auto-resume from latest
  - failure handling: NaN/inf loss detection -> restore last checkpoint and
    skip the offending batch (the driver-level analogue of replica restart)
  - straggler mitigation hook: per-step wall-time EMA; steps slower than
    ``straggler_factor`` x EMA are logged and counted (on real fleets this
    signal feeds the re-balancer in distributed/elastic.py)
  - optional int8 gradient compression with error feedback
    (distributed/compression.py) before the optimizer update
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import OptConfig, apply_updates, init_state


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 500
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    keep: int = 3
    log_every: int = 50
    straggler_factor: float = 3.0
    grad_compression: bool = False
    opt: OptConfig = dataclasses.field(default_factory=OptConfig)


class Trainer:
    def __init__(self, loss_fn: Callable, params: Dict[str, Any],
                 cfg: TrainerConfig):
        self.cfg = cfg
        self.params = params
        self.opt_state = init_state(params)
        self.step = 0
        self.loss_fn = loss_fn
        self.metrics_log: list = []
        self.straggler_steps = 0
        self.recoveries = 0
        if cfg.grad_compression:
            from repro.distributed.compression import CompressedState
            self.comp_state = CompressedState.init(params)
        else:
            self.comp_state = None
        self._build_step()
        if cfg.ckpt_dir:
            restored = ckpt_lib.restore_latest(cfg.ckpt_dir)
            if restored is not None:
                state, meta = restored
                self.params = state["params"]
                self.opt_state = state["opt"]
                self.step = int(meta["step"])

    def _build_step(self):
        ocfg = self.cfg.opt
        lfn = self.loss_fn
        compress = self.cfg.grad_compression

        def train_step(params, opt_state, comp_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lfn, has_aux=True)(params, batch)
            if compress:
                from repro.distributed.compression import (
                    compress_decompress)
                grads, comp_state = compress_decompress(grads, comp_state)
            params, opt_state, om = apply_updates(params, grads, opt_state,
                                                  ocfg)
            metrics = dict(metrics)
            metrics.update(om)
            metrics["loss"] = loss
            return params, opt_state, comp_state, metrics

        self._step_fn = jax.jit(train_step, donate_argnums=(0, 1, 2))

    def run(self, batches: Iterator[Dict[str, Any]],
            steps: Optional[int] = None) -> Dict[str, Any]:
        cfg = self.cfg
        target = self.step + (steps or cfg.total_steps)
        ema = None
        last_metrics: Dict[str, Any] = {}
        while self.step < target:
            batch = next(batches)
            t0 = time.time()
            params, opt, comp, metrics = self._step_fn(
                self.params, self.opt_state, self.comp_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if not np.isfinite(loss):
                # failure path: restore last good state, skip batch
                self.recoveries += 1
                if cfg.ckpt_dir:
                    restored = ckpt_lib.restore_latest(cfg.ckpt_dir)
                    if restored is not None:
                        state, meta = restored
                        self.params = state["params"]
                        self.opt_state = state["opt"]
                        self.step = int(meta["step"])
                        self._build_step()  # donated buffers were consumed
                        continue
                # no checkpoint yet: just skip the batch
                self._build_step()
                continue
            self.params, self.opt_state, self.comp_state = params, opt, comp
            self.step += 1
            last_metrics = {k: float(v) for k, v in metrics.items()}
            if ema is None:
                ema = dt
            elif dt > cfg.straggler_factor * ema:
                self.straggler_steps += 1
                ema = 0.9 * ema + 0.1 * dt
            else:
                ema = 0.9 * ema + 0.1 * dt
            if self.step % cfg.log_every == 0:
                self.metrics_log.append({"step": self.step, **last_metrics})
            if cfg.ckpt_dir and self.step % cfg.ckpt_every == 0:
                ckpt_lib.save(cfg.ckpt_dir, self.step,
                              {"params": self.params, "opt": self.opt_state},
                              keep=cfg.keep)
        if cfg.ckpt_dir:
            ckpt_lib.save(cfg.ckpt_dir, self.step,
                          {"params": self.params, "opt": self.opt_state},
                          keep=cfg.keep)
        return last_metrics


def batch_iterator(x: np.ndarray, y: np.ndarray, batch: int, seed: int = 0,
                   weights: Optional[np.ndarray] = None
                   ) -> Iterator[Dict[str, Any]]:
    rng = np.random.default_rng(seed)
    n = len(y)
    while True:
        idx = rng.integers(0, n, batch)
        b = {"payload": jnp.asarray(x[idx]), "label": jnp.asarray(y[idx])}
        if weights is not None:
            b["weight"] = jnp.asarray(weights[idx], jnp.float32)
        yield b
