"""Sharded npz checkpointing with atomic rename, keep-k and async writes.

Fault-tolerance substrate: a step is only visible once its directory is
atomically renamed into place, so a preempted writer never corrupts the
latest checkpoint; ``restore_latest`` picks the newest complete step.
Elastic scaling: checkpoints are mesh-agnostic (full arrays, gathered), so
restoring onto a different mesh/pspec set just reshards (see
distributed/elastic.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SENTINEL = "COMPLETE"


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    if isinstance(tree, dict):
        out = {}
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}␟"))
        return out
    return {prefix[:-1]: tree}


def _unflatten(flat: Dict[str, Any]) -> Any:
    tree: Dict[str, Any] = {}
    for k, v in flat.items():
        parts = k.split("␟")
        cur = tree
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return tree


def save(ckpt_dir: str, step: int, state: Dict[str, Any],
         keep: int = 3, meta: Optional[Dict] = None) -> str:
    """Write {params, opt, ...} pytree; atomic via tmp dir + rename."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "state.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "time": time.time(), **(meta or {})}, f)
    with open(os.path.join(tmp, _SENTINEL), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def list_steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in sorted(os.listdir(ckpt_dir)):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, d, _SENTINEL)):
            out.append(int(d.split("_")[1]))
    return out


def restore(ckpt_dir: str, step: int) -> Tuple[Dict[str, Any], Dict]:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "state.npz"))
    flat = {k: jnp.asarray(data[k]) for k in data.files}
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return _unflatten(flat), meta


def restore_latest(ckpt_dir: str) -> Optional[Tuple[Dict, Dict]]:
    steps = list_steps(ckpt_dir)
    if not steps:
        return None
    return restore(ckpt_dir, steps[-1])


class AsyncCheckpointer:
    """Overlap checkpoint writes with training (one in flight)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, state: Dict[str, Any],
             meta: Optional[Dict] = None) -> None:
        self.wait()
        # device_get now so training can mutate buffers immediately
        host_state = jax.tree.map(np.asarray, state)
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_state),
            kwargs={"keep": self.keep, "meta": meta}, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
