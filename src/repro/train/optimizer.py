"""Hand-rolled AdamW + schedules on flat param dicts (no optax offline).

States are fp32 regardless of param dtype (bf16 training with fp32 moments —
the standard large-model recipe).  Opt-state pytrees mirror the param tree so
the same PartitionSpecs shard them (m/v inherit the param's spec).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # "cosine" | "constant"


def schedule_lr(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(F32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def init_state(params: Dict[str, Any]) -> Dict[str, Any]:
    zeros = {k: jnp.zeros(v.shape, F32) for k, v in params.items()}
    return {"m": zeros,
            "v": {k: jnp.zeros(v.shape, F32) for k, v in params.items()},
            "step": jnp.zeros((), jnp.int32)}


def abstract_state(params: Dict[str, Any]) -> Dict[str, Any]:
    zeros = {k: jax.ShapeDtypeStruct(v.shape, F32) for k, v in params.items()}
    return {"m": zeros,
            "v": {k: jax.ShapeDtypeStruct(v.shape, F32)
                  for k, v in params.items()},
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def global_norm(tree: Dict[str, Any]) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(v.astype(F32))) for v in tree.values())
    return jnp.sqrt(sq)


def apply_updates(params: Dict[str, Any], grads: Dict[str, Any],
                  state: Dict[str, Any], cfg: OptConfig
                  ) -> Tuple[Dict[str, Any], Dict[str, Any], Dict[str, Any]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.asarray(1.0, F32)
    b1c = 1.0 - cfg.b1 ** step.astype(F32)
    b2c = 1.0 - cfg.b2 ** step.astype(F32)
    new_p, new_m, new_v = {}, {}, {}
    for k, p in params.items():
        g = grads[k].astype(F32) * clip
        m = cfg.b1 * state["m"][k] + (1 - cfg.b1) * g
        v = cfg.b2 * state["v"][k] + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        upd = mh / (jnp.sqrt(vh) + cfg.eps)
        # decoupled weight decay: skip 1-d params (norms, biases)
        if cfg.weight_decay > 0 and p.ndim >= 2:
            upd = upd + cfg.weight_decay * p.astype(F32)
        new_p[k] = (p.astype(F32) - lr * upd).astype(p.dtype)
        new_m[k] = m
        new_v[k] = v
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


def make_train_step(loss_fn: Callable, opt_cfg: OptConfig) -> Callable:
    """loss_fn(params, batch) -> (loss, metrics). Returns jittable step."""

    def train_step(params, state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, state, opt_metrics = apply_updates(params, grads, state,
                                                   opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, state, metrics

    return train_step
