"""LM serving engine: prefill/decode with KV cache + FENIX admission gate.

Static-batch decode loop over the uniform Model API (works for every
assigned arch): allocate the cache at prefill_len + max_new, run
``decode_step`` repeatedly, optionally with int8 weights (Model Engine
quantization) and the ServeGate admitting requests at the measured decode
throughput — the full FENIX pattern applied to LM inference.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.gate import GateConfig, ServeGate
from repro.models import api


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    greedy: bool = True
    quant: str = "none"          # "none" | "int8"
    gate_backend_rate: Optional[float] = None  # req/s; None = ungated


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Dict[str, Any],
                 scfg: ServeConfig):
        self.cfg = cfg
        self.scfg = scfg
        if scfg.quant == "int8":
            # FENIX Model Engine INT8 applied to the LM weights
            _, axes = api.init_params(cfg, abstract=True)
            params, _ = api.quantize_for_serving(cfg, params, axes)
        self.params = params
        self._decode = jax.jit(
            lambda p, c, t: api.decode_step(p, cfg, c, t))
        self.gate: Optional[ServeGate] = None
        if scfg.gate_backend_rate:
            self.gate = ServeGate(GateConfig(
                backend_rate=scfg.gate_backend_rate))

    def generate(self, batch: Dict[str, Any]) -> Dict[str, Any]:
        """batch: tokens [B,S] (+ src_embeds/image_embeds). Greedy decode."""
        cfg, scfg = self.cfg, self.scfg
        b, s = batch["tokens"].shape
        cache, logits = api.prefill(self.params, cfg, batch)
        cache = api.grow_cache(cfg, cache, b, s, s + scfg.max_new_tokens,
                               src_len=batch.get("src_embeds",
                                                 batch["tokens"]).shape[1])
        toks = [jnp.argmax(logits, -1).astype(jnp.int32)]
        t0 = time.time()
        for _ in range(scfg.max_new_tokens - 1):
            cache, logits = self._decode(self.params, cache, toks[-1])
            toks.append(jnp.argmax(logits, -1).astype(jnp.int32))
        dt = time.time() - t0
        out = jnp.stack(toks, axis=1)
        return {"tokens": out,
                "decode_tok_per_s": (scfg.max_new_tokens - 1) * b
                / max(dt, 1e-9)}

    def serve_requests(self, arrivals: List[Dict[str, Any]]
                       ) -> Dict[str, Any]:
        """Gated request admission: each arrival {stream, t_us, batch}."""
        admitted, denied = [], 0
        for req in arrivals:
            if self.gate is None or self.gate.offer(req["stream"],
                                                    req["t_us"]):
                admitted.append(req)
            else:
                denied += 1
        results = [self.generate(r["batch"]) for r in admitted]
        return {"admitted": len(admitted), "denied": denied,
                "results": results,
                "gate_stats": None if self.gate is None else
                {"admitted": self.gate.admitted,
                 "denied": self.gate.denied}}
