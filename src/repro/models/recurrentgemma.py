"""RecurrentGemma / Griffin: RG-LRU recurrent blocks + local-attention, 2:1.

Layer pattern (recurrent, recurrent, attention) repeating; each layer is a
temporal block + GeGLU MLP with pre-norms and residuals.

Recurrent block: x -> [gelu(W_gate x)] * RG_LRU(conv1d(W_in x)) -> W_out.
RG-LRU: r_t = sigma(block_diag(W_a) x_t); i_t = sigma(block_diag(W_i) x_t)
        log a_t = -c * softplus(Lambda) * r_t   (c = 8)
        h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
Parallel over time via jax.lax.associative_scan (log-depth on TPU).

Attention block: MQA (kv=1) with rope and a 2048-token sliding window; the
decode cache is a *ring buffer* of window slots — the same circular-buffer
trick as FENIX's Buffer Manager (§4.3), reused here for O(window) memory.
Sub-quadratic => runs long_500k.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import Registrar, maybe_scan, shard, subtree
from repro.models.transformer import _Stacked, _remat, _gqa_qkv

F32 = jnp.float32
_LRU_C = 8.0
_N_BLOCKS = 16  # block-diagonal gate projections (Griffin appendix)


def _w(cfg: ModelConfig) -> int:
    return cfg.hybrid.lru_width or cfg.d_model


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_recurrent(reg, cfg: ModelConfig) -> None:
    d, w = cfg.d_model, _w(cfg)
    L.init_rmsnorm(reg, "ln", d)
    reg.param("wgate/w", (d, w), ("embed", "lru"), scale=d ** -0.5)
    reg.param("win/w", (d, w), ("embed", "lru"), scale=d ** -0.5)
    reg.param("conv/w", (cfg.hybrid.conv_width, w), ("conv", "lru"),
              scale=cfg.hybrid.conv_width ** -0.5)
    reg.param("conv/b", (w,), ("lru",), init="zeros")
    nb = _N_BLOCKS
    reg.param("wa/w", (nb, w // nb, w // nb), ("blocks", "lru", "lru"),
              scale=(w // nb) ** -0.5)
    reg.param("wa/b", (w,), ("lru",), init="zeros")
    reg.param("wi/w", (nb, w // nb, w // nb), ("blocks", "lru", "lru"),
              scale=(w // nb) ** -0.5)
    reg.param("wi/b", (w,), ("lru",), init="zeros")
    reg.param("lam", (w,), ("lru",), init="uniform", scale=1.0, dtype=F32)
    reg.param("wout/w", (w, d), ("lru", "embed"), scale=w ** -0.5)


def _init_attention(reg, cfg: ModelConfig) -> None:
    d, h, dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    L.init_rmsnorm(reg, "ln", d)
    reg.param("attn/wq/w", (d, h, dh), ("embed", "heads", "head_dim"),
              scale=d ** -0.5)
    reg.param("attn/wk/w", (d, cfg.num_kv_heads, dh),
              ("embed", "kv_heads", "head_dim"), scale=d ** -0.5)
    reg.param("attn/wv/w", (d, cfg.num_kv_heads, dh),
              ("embed", "kv_heads", "head_dim"), scale=d ** -0.5)
    reg.param("attn/wo/w", (h, dh, d), ("heads", "head_dim", "embed"),
              scale=(h * dh) ** -0.5)


def _init_mlp(reg, cfg: ModelConfig) -> None:
    L.init_rmsnorm(reg, "ln_mlp", cfg.d_model)
    L.init_glu_mlp(reg, "mlp", cfg.d_model, cfg.d_ff)


def _pattern_split(cfg: ModelConfig):
    pat = cfg.hybrid.pattern
    n_super = cfg.num_layers // len(pat)
    tail = cfg.num_layers % len(pat)
    return pat, n_super, pat[:tail]


def init_params(reg: Registrar, cfg: ModelConfig) -> None:
    from repro.models.transformer import _Prefixed

    L.init_embedding(reg, "embed", cfg.vocab_size, cfg.d_model)
    pat, n_super, tail = _pattern_split(cfg)
    stk = _Stacked(reg, n_super, "sb/")
    for j, kind in enumerate(pat):
        sub = _Prefixed(stk, f"l{j}/")
        (_init_recurrent if kind == "recurrent" else _init_attention)(sub, cfg)
        _init_mlp(sub, cfg)
    for j, kind in enumerate(tail):
        sub = _Prefixed(reg, f"tail/l{j}/")
        (_init_recurrent if kind == "recurrent" else _init_attention)(sub, cfg)
        _init_mlp(sub, cfg)
    L.init_rmsnorm(reg, "ln_f", cfg.d_model)
    if not cfg.tie_embeddings:
        reg.param("head/w", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                  scale=cfg.d_model ** -0.5)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def _block_diag(p, name: str, x: jax.Array) -> jax.Array:
    """x [..., W] through block-diagonal linear [nb, W/nb, W/nb]."""
    nb = p[f"{name}/w"].shape[0]
    shp = x.shape
    xr = x.reshape(*shp[:-1], nb, shp[-1] // nb)
    y = jnp.einsum("...ni,nio->...no", xr, L.W(p, f"{name}/w"))
    return y.reshape(shp) + p[f"{name}/b"]


def _rg_lru_seq(p, x: jax.Array, h0=None) -> Tuple[jax.Array, jax.Array]:
    """x [B,S,W] -> (y [B,S,W], h_last [B,W]); linear recurrence via a-scan."""
    r = jax.nn.sigmoid(_block_diag(p, "wa", x).astype(F32))
    i = jax.nn.sigmoid(_block_diag(p, "wi", x).astype(F32))
    log_a = -_LRU_C * jax.nn.softplus(p["lam"]) * r          # [B,S,W] fp32
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * x.astype(F32))

    def combine(l, rr):
        al, bl = l
        ar, br = rr
        return al * ar, bl * ar + br

    if h0 is not None:
        # fold the carry-in into the first step: b_0 += a_0 * h0
        gated = gated.at[:, 0].add(a[:, 0] * h0)
    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(x.dtype), h[:, -1]


def _recurrent_block_seq(p, cfg, x, state=None):
    """x [B,S,d]. state = (conv_tail, h0) or None. Returns (y, new_state)."""
    hx = L.rmsnorm(p, "ln", x, cfg.norm_eps)
    gate = jax.nn.gelu(L.dense(p, "wgate", hx, "...d,dw->...w"))
    u = L.dense(p, "win", hx, "...d,dw->...w")
    u = shard(u, "batch", "seq", "lru")
    kw = cfg.hybrid.conv_width
    if state is not None:
        conv0, h0 = state
        u_in = jnp.concatenate([conv0, u], axis=1)
        conv_tail = u_in[:, -(kw - 1):]
        from repro.models.mamba2 import _causal_conv
        uc = _causal_conv(u_in, p["conv/w"], p["conv/b"])[:, -(u.shape[1]):]
    else:
        h0 = None
        from repro.models.mamba2 import _causal_conv
        conv_tail = u[:, max(0, u.shape[1] - (kw - 1)):]
        if conv_tail.shape[1] < kw - 1:
            conv_tail = jnp.pad(
                conv_tail, ((0, 0), (kw - 1 - conv_tail.shape[1], 0), (0, 0)))
        uc = _causal_conv(u, p["conv/w"], p["conv/b"])
    y, h_last = _rg_lru_seq(p, uc, h0=h0)
    out = L.dense(p, "wout", gate * y, "...w,wd->...d")
    return x + out, (conv_tail, h_last)


def _recurrent_block_step(p, cfg, x, state):
    """Single token. x [B,d]; state (conv [B,K-1,W], h [B,W])."""
    conv0, h0 = state
    hx = L.rmsnorm(p, "ln", x, cfg.norm_eps)
    gate = jax.nn.gelu(L.dense(p, "wgate", hx, "...d,dw->...w"))
    u = L.dense(p, "win", hx, "...d,dw->...w")
    win = jnp.concatenate([conv0, u[:, None]], axis=1)       # [B,K,W]
    uc = jnp.einsum("bkw,kw->bw", win, p["conv/w"]) + p["conv/b"]
    r = jax.nn.sigmoid(_block_diag(p, "wa", uc).astype(F32))
    i = jax.nn.sigmoid(_block_diag(p, "wi", uc).astype(F32))
    log_a = -_LRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    h = a * h0 + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * uc.astype(F32))
    out = L.dense(p, "wout", gate * h.astype(x.dtype), "...w,wd->...d")
    return x + out, (win[:, 1:], h)


# ---------------------------------------------------------------------------
# Attention layer (MQA + window; ring-buffer decode cache)
# ---------------------------------------------------------------------------


def _attn_block_seq(p, cfg, x, emit_cache=False):
    hx = L.rmsnorm(p, "ln", x, cfg.norm_eps)
    win = cfg.hybrid.attention_window
    positions = jnp.arange(x.shape[1])[None, :]
    q, k, v = _gqa_qkv(p, cfg, hx, positions)
    o = L.attention(q, k, v, causal=True, impl=cfg.attention_impl,
                    chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
                    window=win)
    out = L.dense(p, "attn/wo", o, "...hk,hkd->...d")
    if not emit_cache:
        return x + out, None
    # ring cache: the last `win` K/V entries, in ring order slot = pos % win
    s = x.shape[1]
    if s >= win:
        kr = k[:, -win:]
        vr = v[:, -win:]
        # rotate so that slot index = position % win
        shift = s % win
        kr = jnp.roll(kr, shift, axis=1)
        vr = jnp.roll(vr, shift, axis=1)
    else:
        kr = jnp.pad(k, ((0, 0), (0, win - s), (0, 0), (0, 0)))
        vr = jnp.pad(v, ((0, 0), (0, win - s), (0, 0), (0, 0)))
    return x + out, {"k": kr, "v": vr}


def _attn_block_step(p, cfg, x, cache_l, pos):
    b = x.shape[0]
    win = cache_l["k"].shape[1]  # ring size
    hx = L.rmsnorm(p, "ln", x, cfg.norm_eps)
    posv = jnp.full((b,), pos)
    q = L.dense(p, "attn/wq", hx, "...d,dhk->...hk")
    k = L.dense(p, "attn/wk", hx, "...d,dhk->...hk")
    v = L.dense(p, "attn/wv", hx, "...d,dhk->...hk")
    q = L.rope(q, posv[:, None], cfg.rope_theta)
    k = L.rope(k, posv[:, None], cfg.rope_theta)
    slot = jnp.mod(pos, win)
    kc = jax.lax.dynamic_update_slice_in_dim(cache_l["k"], k[:, None], slot, 1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache_l["v"], v[:, None], slot, 1)
    n_valid = jnp.minimum(pos + 1, win)
    o = L.decode_attention(q, kc, vc, jnp.full((b,), n_valid))
    out = L.dense(p, "attn/wo", o, "...hk,hkd->...d")
    return x + out, {"k": kc, "v": vc}


def _mlp_block(p, cfg, x):
    h = L.rmsnorm(p, "ln_mlp", x, cfg.norm_eps)
    return x + L.glu_mlp(p, "mlp", h, cfg.mlp_act)


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------


def _layer_seq(p_l, cfg, x, kind, emit_cache):
    if kind == "recurrent":
        x, st = _recurrent_block_seq(p_l, cfg, x)
        cache = {"conv": st[0], "h": st[1]} if emit_cache else None
    else:
        x, cache = _attn_block_seq(p_l, cfg, x, emit_cache=emit_cache)
    x = _mlp_block(p_l, cfg, x)
    return shard(x, "batch", "act_seq", "embed"), cache


def _run_seq(params, cfg: ModelConfig, tokens, emit_cache: bool):
    x = L.embed(params, "embed", tokens).astype(cfg.activation_dtype)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)   # gemma embed scaling
    x = shard(x, "batch", "seq", "embed")
    pat, n_super, tail = _pattern_split(cfg)
    stacked = subtree(params, "sb/")

    def body(x, p_sb):
        caches = {}
        for j, kind in enumerate(pat):
            p_l = subtree(p_sb, f"l{j}/")
            fn = _remat(lambda pp, xx, kk=kind: _layer_seq(
                pp, cfg, xx, kk, emit_cache), cfg) if not emit_cache else \
                (lambda pp, xx, kk=kind: _layer_seq(pp, cfg, xx, kk, True))
            x, c = fn(p_l, x)
            if emit_cache and c is not None:
                for ck, cv in c.items():
                    caches[f"l{j}/{ck}"] = cv
        return x, caches

    x, sb_caches = maybe_scan(body, x, stacked, cfg.scan_layers)
    tail_caches = {}
    for j, kind in enumerate(tail):
        p_l = subtree(params, f"tail/l{j}/")
        x, c = _layer_seq(p_l, cfg, x, kind, emit_cache)
        if emit_cache and c is not None:
            for ck, cv in c.items():
                tail_caches[f"tail/l{j}/{ck}"] = cv
    x = L.rmsnorm(params, "ln_f", x, cfg.norm_eps)
    return x, sb_caches, tail_caches


def forward_train(params, cfg, tokens):
    x, _, _ = _run_seq(params, cfg, tokens, emit_cache=False)
    logits = L.logits_head(params, x,
                           None if cfg.tie_embeddings else "head", "embed")
    return logits, jnp.zeros((), F32)


def loss_fn(params, cfg, batch):
    logits, _ = forward_train(params, cfg, batch["tokens"])
    ce = L.softmax_xent(logits, batch["labels"], batch.get("mask"))
    return ce, {"ce": ce}


def prefill(params, cfg, tokens):
    x, sb_caches, tail_caches = _run_seq(params, cfg, tokens, emit_cache=True)
    logits = L.logits_head(params, x[:, -1],
                           None if cfg.tie_embeddings else "head", "embed")
    cache = {f"sb/{k}": v for k, v in sb_caches.items()}
    cache.update(tail_caches)
    cache["pos"] = jnp.asarray(tokens.shape[1], jnp.int32)
    return cache, logits


def decode_step(params, cfg, cache, tokens):
    pos = cache["pos"]
    x = L.embed(params, "embed", tokens).astype(cfg.activation_dtype)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    pat, n_super, tail = _pattern_split(cfg)
    stacked = subtree(params, "sb/")
    sb_cache = subtree(cache, "sb/")

    def body(x, xs):
        p_sb, c_sb = xs
        new_c = {}
        for j, kind in enumerate(pat):
            p_l = subtree(p_sb, f"l{j}/")
            c_l = subtree(c_sb, f"l{j}/")
            if kind == "recurrent":
                x, st = _recurrent_block_step(p_l, cfg, x,
                                              (c_l["conv"], c_l["h"]))
                new_c[f"l{j}/conv"], new_c[f"l{j}/h"] = st
            else:
                x, c2 = _attn_block_step(p_l, cfg, x, c_l, pos)
                new_c[f"l{j}/k"], new_c[f"l{j}/v"] = c2["k"], c2["v"]
            x = _mlp_block(p_l, cfg, x)
        return x, new_c

    x, upd = maybe_scan(body, x, (stacked, sb_cache), cfg.scan_layers)
    new_cache = {f"sb/{k}": v for k, v in upd.items()}
    for j, kind in enumerate(tail):
        p_l = subtree(params, f"tail/l{j}/")
        c_l = subtree(cache, f"tail/l{j}/")
        if kind == "recurrent":
            x, st = _recurrent_block_step(p_l, cfg, x, (c_l["conv"], c_l["h"]))
            new_cache[f"tail/l{j}/conv"], new_cache[f"tail/l{j}/h"] = st
        else:
            x, c2 = _attn_block_step(p_l, cfg, x, c_l, pos)
            new_cache[f"tail/l{j}/k"] = c2["k"]
            new_cache[f"tail/l{j}/v"] = c2["v"]
        x = _mlp_block(p_l, cfg, x)
    x = L.rmsnorm(params, "ln_f", x, cfg.norm_eps)
    logits = L.logits_head(params, x,
                           None if cfg.tie_embeddings else "head", "embed")
    new_cache["pos"] = pos + 1
    return new_cache, logits


def cache_spec(cfg: ModelConfig, batch: int, smax: int) -> Dict[str, Tuple]:
    pat, n_super, tail = _pattern_split(cfg)
    w = _w(cfg)
    kw = cfg.hybrid.conv_width
    win = cfg.hybrid.attention_window
    dt = jnp.bfloat16
    out: Dict[str, Tuple] = {}

    def rec_entries(prefix, lead=()):
        la = ("layers",) if lead else ()
        out[f"{prefix}conv"] = ((*lead, batch, kw - 1, w), dt,
                                (*la, "batch", "conv", "lru"))
        out[f"{prefix}h"] = ((*lead, batch, w), F32, (*la, "batch", "lru"))

    def attn_entries(prefix, lead=()):
        la = ("layers",) if lead else ()
        shp = (*lead, batch, win, cfg.num_kv_heads, cfg.head_dim)
        ax = (*la, "batch", "kv_seq", "kv_heads", "head_dim")
        out[f"{prefix}k"] = (shp, dt, ax)
        out[f"{prefix}v"] = (shp, dt, ax)

    for j, kind in enumerate(pat):
        (rec_entries if kind == "recurrent" else attn_entries)(
            f"sb/l{j}/", lead=(n_super,))
    for j, kind in enumerate(tail):
        (rec_entries if kind == "recurrent" else attn_entries)(f"tail/l{j}/")
    out["pos"] = ((), jnp.int32, ())
    return out
