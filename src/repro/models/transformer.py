"""Decoder-only transformer LM: dense + MoE, GQA + MLA, all config-driven.

Covers deepseek-v2-236b (MLA + MoE), qwen2-moe-a2.7b (MoE + shared gated
expert), llama3.2-1b / qwen2.5-14b / qwen3-4b / gemma-7b (dense GQA variants).

Three entry points per the uniform Model API:
  - ``loss_fn``     (train_4k)      — scan-over-layers + remat, CE + MoE aux
  - ``prefill``     (prefill_32k)   — emits the KV cache + last-position logits
  - ``decode_step`` (decode_32k)    — one token against a seq_len KV cache

Cache layouts (stacked over layers for scan):
  GQA: k,v [L, B, Smax, Hkv, Dh]     MLA: ckv [L, B, Smax, R], kpe [L,B,Smax,Dr]
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import Registrar, maybe_scan, shard, subtree

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


class _Stacked:
    """Registrar view that prepends a stacking dim (scan over layers)."""

    def __init__(self, reg: Registrar, n: int, prefix: str):
        self.reg, self.n, self.prefix = reg, n, prefix

    def param(self, path, shape, axes, **kw):
        return self.reg.param(f"{self.prefix}{path}", (self.n, *shape),
                              ("layers", *axes), **kw)


def _init_attention(reg, cfg: ModelConfig, path: str = "attn") -> None:
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if cfg.attention == "mla":
        r, dr, dn, dv = (cfg.kv_lora_rank, cfg.qk_rope_head_dim,
                         cfg.qk_nope_head_dim, cfg.v_head_dim)
        if cfg.q_lora_rank:
            reg.param(f"{path}/wdq/w", (d, cfg.q_lora_rank),
                      ("embed", "q_lora"), scale=d ** -0.5)
            reg.param(f"{path}/q_norm/scale", (cfg.q_lora_rank,), ("q_lora",),
                      init="ones", dtype=F32)
            reg.param(f"{path}/wuq/w", (cfg.q_lora_rank, h, dn + dr),
                      ("q_lora", "heads", "qk_dim"),
                      scale=cfg.q_lora_rank ** -0.5)
        else:
            reg.param(f"{path}/wq/w", (d, h, dn + dr),
                      ("embed", "heads", "qk_dim"), scale=d ** -0.5)
        reg.param(f"{path}/wdkv/w", (d, r), ("embed", "kv_lora"),
                  scale=d ** -0.5)
        reg.param(f"{path}/kv_norm/scale", (r,), ("kv_lora",), init="ones",
                  dtype=F32)
        reg.param(f"{path}/wkr/w", (d, dr), ("embed", "qk_dim"),
                  scale=d ** -0.5)
        reg.param(f"{path}/wuk/w", (r, h, dn), ("kv_lora", "heads", "qk_dim"),
                  scale=r ** -0.5)
        reg.param(f"{path}/wuv/w", (r, h, dv), ("kv_lora", "heads", "v_dim"),
                  scale=r ** -0.5)
        reg.param(f"{path}/wo/w", (h, dv, d), ("heads", "v_dim", "embed"),
                  scale=(h * dv) ** -0.5)
        return
    # GQA
    reg.param(f"{path}/wq/w", (d, h, dh), ("embed", "heads", "head_dim"),
              scale=d ** -0.5)
    reg.param(f"{path}/wk/w", (d, hkv, dh), ("embed", "kv_heads", "head_dim"),
              scale=d ** -0.5)
    reg.param(f"{path}/wv/w", (d, hkv, dh), ("embed", "kv_heads", "head_dim"),
              scale=d ** -0.5)
    reg.param(f"{path}/wo/w", (h, dh, d), ("heads", "head_dim", "embed"),
              scale=(h * dh) ** -0.5)
    if cfg.qkv_bias:
        reg.param(f"{path}/wq/b", (h, dh), ("heads", "head_dim"), init="zeros")
        reg.param(f"{path}/wk/b", (hkv, dh), ("kv_heads", "head_dim"),
                  init="zeros")
        reg.param(f"{path}/wv/b", (hkv, dh), ("kv_heads", "head_dim"),
                  init="zeros")
    if cfg.qk_norm:
        reg.param(f"{path}/qnorm/scale", (dh,), ("head_dim",), init="ones",
                  dtype=F32)
        reg.param(f"{path}/knorm/scale", (dh,), ("head_dim",), init="ones",
                  dtype=F32)


def _init_block(reg, cfg: ModelConfig, mlp_kind: str, dense_ff: int = 0) -> None:
    L.init_rmsnorm(reg, "ln_attn", cfg.d_model)
    _init_attention(reg, cfg)
    L.init_rmsnorm(reg, "ln_mlp", cfg.d_model)
    if mlp_kind == "dense":
        L.init_glu_mlp(reg, "mlp", cfg.d_model, dense_ff or cfg.d_ff)
    else:
        L.init_moe(reg, "moe", cfg.d_model, cfg.moe)


def init_params(reg: Registrar, cfg: ModelConfig) -> None:
    L.init_embedding(reg, "embed", cfg.vocab_size, cfg.d_model)
    n_dense_first = cfg.moe.first_dense_layers if cfg.moe.num_experts else 0
    for i in range(n_dense_first):
        sub = _Prefixed(reg, f"layer{i}/")
        _init_block(sub, cfg, "dense", dense_ff=cfg.moe.first_dense_d_ff)
    n_scan = cfg.num_layers - n_dense_first
    stk = _Stacked(reg, n_scan, "layers/")
    _init_block(stk, cfg, "moe" if cfg.moe.num_experts else "dense")
    L.init_rmsnorm(reg, "ln_f", cfg.d_model)
    if not cfg.tie_embeddings:
        reg.param("head/w", (cfg.d_model, cfg.vocab_size),
                  ("embed", "vocab"), scale=cfg.d_model ** -0.5)


class _Prefixed:
    def __init__(self, reg, prefix: str):
        self.reg, self.prefix = reg, prefix

    def param(self, path, shape, axes, **kw):
        return self.reg.param(f"{self.prefix}{path}", shape, axes, **kw)


# ---------------------------------------------------------------------------
# Attention apply (all modes)
# ---------------------------------------------------------------------------


def _gqa_qkv(p, cfg: ModelConfig, x, positions):
    q = L.dense(p, "attn/wq", x, "...d,dhk->...hk")
    k = L.dense(p, "attn/wk", x, "...d,dhk->...hk")
    v = L.dense(p, "attn/wv", x, "...d,dhk->...hk")
    if cfg.qk_norm:
        q = L.rmsnorm_1d(p["attn/qnorm/scale"], q, cfg.norm_eps)
        k = L.rmsnorm_1d(p["attn/knorm/scale"], k, cfg.norm_eps)
    # rope over the seq axis (axis -3 carries S for [B,S,H,D], absent for decode)
    q = L.rope(q.swapaxes(-2, -3), positions, cfg.rope_theta).swapaxes(-2, -3) \
        if x.ndim == 3 else L.rope(q, positions[..., None], cfg.rope_theta)
    k = L.rope(k.swapaxes(-2, -3), positions, cfg.rope_theta).swapaxes(-2, -3) \
        if x.ndim == 3 else L.rope(k, positions[..., None], cfg.rope_theta)
    return q, k, v


def _attn_train(p, cfg: ModelConfig, x, window=None):
    """x [B,S,d] -> (out [B,S,d], cache_entry)."""
    b, s, d = x.shape
    positions = jnp.arange(s)[None, :]
    if cfg.attention == "mla":
        q, k, v = _mla_qkv_full(p, cfg, x, positions)
    else:
        q, k, v = _gqa_qkv(p, cfg, x, positions)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    o = L.attention(q, k, v, causal=True, impl=cfg.attention_impl,
                    chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
                    window=window)
    o = shard(o, "batch", "seq", "heads", "head_dim")
    return L.dense(p, "attn/wo", o, "...hk,hkd->...d")


def _mla_qkv_full(p, cfg: ModelConfig, x, positions):
    """Decompressed MLA for train/prefill: per-head K/V materialized."""
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        cq = _rms(p["attn/q_norm/scale"],
                  L.dense(p, "attn/wdq", x, "...d,dr->...r"), cfg.norm_eps)
        qh = jnp.einsum("...r,rhk->...hk", cq, L.W(p, "attn/wuq/w"))
    else:
        qh = L.dense(p, "attn/wq", x, "...d,dhk->...hk")
    q_nope, q_pe = qh[..., :dn], qh[..., dn:]
    ckv = _rms(p["attn/kv_norm/scale"],
               L.dense(p, "attn/wdkv", x, "...d,dr->...r"), cfg.norm_eps)
    k_pe = L.dense(p, "attn/wkr", x, "...d,dk->...k")      # [B,S,dr] shared
    q_pe = L.rope(q_pe.swapaxes(-2, -3), positions, cfg.rope_theta).swapaxes(-2, -3)
    k_pe = L.rope(k_pe, positions, cfg.rope_theta)
    k_nope = jnp.einsum("...r,rhk->...hk", ckv, L.W(p, "attn/wuk/w"))
    v = jnp.einsum("...r,rhe->...he", ckv, L.W(p, "attn/wuv/w"))
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[..., None, :],
                                  (*k_nope.shape[:-1], dr))], axis=-1)
    return q, k, v


def _rms(scale, x, eps):
    dt = x.dtype
    x = x.astype(F32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale).astype(dt)


def _attn_prefill(p, cfg: ModelConfig, x, window=None):
    """Returns (out, cache_entry_dict)."""
    b, s, d = x.shape
    positions = jnp.arange(s)[None, :]
    if cfg.attention == "mla":
        # cache the compressed latent (the whole point of MLA)
        ckv = _rms(p["attn/kv_norm/scale"],
                   L.dense(p, "attn/wdkv", x, "...d,dr->...r"), cfg.norm_eps)
        k_pe = L.rope(L.dense(p, "attn/wkr", x, "...d,dk->...k"), positions,
                      cfg.rope_theta)
        out = _attn_train(p, cfg, x, window=window)
        return out, {"ckv": ckv, "kpe": k_pe}
    q, k, v = _gqa_qkv(p, cfg, x, positions)
    o = L.attention(q, k, v, causal=True, impl=cfg.attention_impl,
                    chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
                    window=window)
    out = L.dense(p, "attn/wo", o, "...hk,hkd->...d")
    return out, {"k": _kv_store(cfg, k), "v": _kv_store(cfg, v)}


def _attn_decode(p, cfg: ModelConfig, x, cache_l, pos, window=None):
    """x [B,d]; cache_l per-layer dict; pos scalar. Returns (out, new cache)."""
    b = x.shape[0]
    lengths = jnp.full((b,), pos + 1)
    if cfg.attention == "mla":
        return _mla_decode(p, cfg, x, cache_l, pos, lengths)
    posv = jnp.full((b,), pos)
    q = L.dense(p, "attn/wq", x, "...d,dhk->...hk")
    k = L.dense(p, "attn/wk", x, "...d,dhk->...hk")
    v = L.dense(p, "attn/wv", x, "...d,dhk->...hk")
    if cfg.qk_norm:
        q = L.rmsnorm_1d(p["attn/qnorm/scale"], q, cfg.norm_eps)
        k = L.rmsnorm_1d(p["attn/knorm/scale"], k, cfg.norm_eps)
    q = L.rope(q, posv[:, None], cfg.rope_theta)
    k = L.rope(k, posv[:, None], cfg.rope_theta)
    kc = jax.lax.dynamic_update_slice_in_dim(
        cache_l["k"], _kv_store(cfg, k)[:, None], pos, 1)
    vc = jax.lax.dynamic_update_slice_in_dim(
        cache_l["v"], _kv_store(cfg, v)[:, None], pos, 1)
    o = L.decode_attention(q, _kv_load(cfg, kc), _kv_load(cfg, vc),
                           lengths, window=window)
    out = L.dense(p, "attn/wo", o, "...hk,hkd->...d")
    return out, {"k": kc, "v": vc}


def _mla_decode(p, cfg: ModelConfig, x, cache_l, pos, lengths):
    """Matrix-absorbed MLA decode over the compressed latent cache."""
    dn, dr, r = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.kv_lora_rank
    b = x.shape[0]
    posv = jnp.full((b,), pos)
    if cfg.q_lora_rank:
        cq = _rms(p["attn/q_norm/scale"],
                  L.dense(p, "attn/wdq", x, "...d,dr->...r"), cfg.norm_eps)
        qh = jnp.einsum("br,rhk->bhk", cq, L.W(p, "attn/wuq/w"))
    else:
        qh = L.dense(p, "attn/wq", x, "...d,dhk->...hk")
    q_nope, q_pe = qh[..., :dn], qh[..., dn:]
    q_pe = L.rope(q_pe, posv[:, None], cfg.rope_theta)
    ckv_new = _rms(p["attn/kv_norm/scale"],
                   L.dense(p, "attn/wdkv", x, "...d,dr->...r"), cfg.norm_eps)
    kpe_new = L.rope(L.dense(p, "attn/wkr", x, "...d,dk->...k"),
                     posv, cfg.rope_theta)
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_l["ckv"], ckv_new[:, None], pos, 1)           # [B,Smax,R]
    kpe = jax.lax.dynamic_update_slice_in_dim(
        cache_l["kpe"], kpe_new[:, None], pos, 1)           # [B,Smax,dr]
    ckv_s = shard(ckv, "batch", "kv_seq", "kv_lora")
    # absorb W_UK into q
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope, L.W(p, "attn/wuk/w"))
    s = (jnp.einsum("bhr,bsr->bhs", q_abs.astype(F32), ckv_s.astype(F32))
         + jnp.einsum("bhk,bsk->bhs", q_pe.astype(F32), kpe.astype(F32)))
    s = s * ((dn + dr) ** -0.5)
    mask = jnp.arange(ckv.shape[1])[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None], s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", pr.astype(ckv.dtype), ckv_s)
    v_ctx = jnp.einsum("bhr,rhe->bhe", ctx, L.W(p, "attn/wuv/w"))
    out = L.dense(p, "attn/wo", v_ctx, "bhe,hed->bd")
    return out, {"ckv": ckv, "kpe": kpe}


# ---------------------------------------------------------------------------
# Block (attention + MLP) for every mode
# ---------------------------------------------------------------------------


def _block_apply(p, cfg: ModelConfig, x, mlp_kind: str, *, mode: str,
                 cache_l=None, pos=None, window=None):
    """Returns (x_out, aux_loss, new_cache_entry_or_None)."""
    h = L.rmsnorm(p, "ln_attn", x, cfg.norm_eps)
    new_cache = None
    if mode == "train":
        a = _attn_train(p, cfg, h, window=window)
    elif mode == "prefill":
        a, new_cache = _attn_prefill(p, cfg, h, window=window)
    else:
        a, new_cache = _attn_decode(p, cfg, h, cache_l, pos, window=window)
    x = x + a
    h = L.rmsnorm(p, "ln_mlp", x, cfg.norm_eps)
    aux = jnp.zeros((), F32)
    if mlp_kind == "dense":
        m = L.glu_mlp(p, "mlp", h, cfg.mlp_act)
    else:
        if mode == "decode":
            m, aux = L.moe_ffn(p, "moe", h[:, None], cfg.moe, cfg.mlp_act)
            m = m[:, 0]
        else:
            m, aux = L.moe_ffn(p, "moe", h, cfg.moe, cfg.mlp_act)
    x = x + m
    if x.ndim == 3:
        x = shard(x, "batch", "act_seq", "embed")
    else:
        x = shard(x, "batch", "embed")
    return x, aux, new_cache


def _remat(fn, cfg: ModelConfig):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # "nothing": save nothing, recompute all


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _embed_in(params, cfg: ModelConfig, tokens):
    x = L.embed(params, "embed", tokens).astype(cfg.activation_dtype)
    if cfg.mlp_act == "gelu":          # gemma-family embedding scaling
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return shard(x, "batch", "seq", "embed")


def _n_dense_first(cfg: ModelConfig) -> int:
    return cfg.moe.first_dense_layers if cfg.moe.num_experts else 0


def forward_train(params: Dict, cfg: ModelConfig, tokens: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """tokens [B,S] -> (logits [B,S,V], moe_aux)."""
    x = _embed_in(params, cfg, tokens)
    aux_total = jnp.zeros((), F32)
    for i in range(_n_dense_first(cfg)):
        p_i = subtree(params, f"layer{i}/")
        body = _remat(lambda pp, xx: _block_apply(
            pp, cfg, xx, "dense", mode="train")[:2], cfg)
        x, aux = body(p_i, x)
        aux_total += aux
    mlp_kind = "moe" if cfg.moe.num_experts else "dense"
    stacked = subtree(params, "layers/")

    def body(x, p_l):
        fn = _remat(lambda pp, xx: _block_apply(
            pp, cfg, xx, mlp_kind, mode="train")[:2], cfg)
        x, aux = fn(p_l, x)
        return x, aux

    x, auxes = maybe_scan(body, x, stacked, cfg.scan_layers)
    aux_total += jnp.sum(auxes)
    x = L.rmsnorm(params, "ln_f", x, cfg.norm_eps)
    logits = L.logits_head(params, x,
                           None if cfg.tie_embeddings else "head", "embed")
    return logits, aux_total


def loss_fn(params: Dict, cfg: ModelConfig, batch: Dict) -> Tuple[jax.Array, Dict]:
    logits, aux = forward_train(params, cfg, batch["tokens"])
    ce = L.softmax_xent(logits, batch["labels"], batch.get("mask"))
    return ce + aux, {"ce": ce, "moe_aux": aux}


def prefill(params: Dict, cfg: ModelConfig, tokens: jax.Array
            ) -> Tuple[Dict, jax.Array]:
    """Returns (cache, last-position logits [B,V])."""
    x = _embed_in(params, cfg, tokens)
    head_caches = []
    for i in range(_n_dense_first(cfg)):
        p_i = subtree(params, f"layer{i}/")
        x, _, c = _block_apply(p_i, cfg, x, "dense", mode="prefill")
        head_caches.append(c)
    mlp_kind = "moe" if cfg.moe.num_experts else "dense"
    stacked = subtree(params, "layers/")

    def body(x, p_l):
        x, _, c = _block_apply(p_l, cfg, x, mlp_kind, mode="prefill")
        return x, c

    x, caches = maybe_scan(body, x, stacked, cfg.scan_layers)
    x = L.rmsnorm(params, "ln_f", x, cfg.norm_eps)
    logits = L.logits_head(params, x[:, -1],
                           None if cfg.tie_embeddings else "head", "embed")
    cache: Dict[str, Any] = {f"scan/{k}": v for k, v in caches.items()}
    for i, c in enumerate(head_caches):
        for k, v in c.items():
            cache[f"layer{i}/{k}"] = v
    cache["pos"] = jnp.asarray(tokens.shape[1], jnp.int32)
    return cache, logits


def decode_step(params: Dict, cfg: ModelConfig, cache: Dict,
                tokens: jax.Array) -> Tuple[Dict, jax.Array]:
    """tokens [B] one step; cache from prefill/cache_spec. Returns new cache."""
    pos = cache["pos"]
    x = L.embed(params, "embed", tokens).astype(cfg.activation_dtype)
    if cfg.mlp_act == "gelu":
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = shard(x, "batch", "embed")
    new_cache: Dict[str, Any] = {}
    for i in range(_n_dense_first(cfg)):
        p_i = subtree(params, f"layer{i}/")
        cl = {k.split("/", 1)[1]: v for k, v in cache.items()
              if k.startswith(f"layer{i}/")}
        x, _, c = _block_apply(p_i, cfg, x, "dense", mode="decode",
                               cache_l=cl, pos=pos)
        for k, v in c.items():
            new_cache[f"layer{i}/{k}"] = v
    mlp_kind = "moe" if cfg.moe.num_experts else "dense"
    stacked = subtree(params, "layers/")
    scan_cache = {k[len("scan/"):]: v for k, v in cache.items()
                  if k.startswith("scan/")}

    def body(x, xs):
        p_l, cl = xs
        x, _, c = _block_apply(p_l, cfg, x, mlp_kind, mode="decode",
                               cache_l=cl, pos=pos)
        return x, c

    x, upd = maybe_scan(body, x, (stacked, scan_cache), cfg.scan_layers)
    for k, v in upd.items():
        new_cache[f"scan/{k}"] = v
    x = L.rmsnorm(params, "ln_f", x, cfg.norm_eps)
    logits = L.logits_head(params, x,
                           None if cfg.tie_embeddings else "head", "embed")
    new_cache["pos"] = pos + 1
    return new_cache, logits


# ---------------------------------------------------------------------------
# Cache specs (for dry-run ShapeDtypeStructs and serving allocation)
# ---------------------------------------------------------------------------


_KV_SCALE = 64.0  # static int8 KV grid (per-tensor; see DESIGN notes)


def _kv_store(cfg: ModelConfig, x):
    if cfg.kv_cache_dtype == "int8":
        return jnp.clip(jnp.round(x.astype(jnp.float32) * _KV_SCALE),
                        -127, 127).astype(jnp.int8)
    return x


def _kv_load(cfg: ModelConfig, x):
    if cfg.kv_cache_dtype == "int8":
        return (x.astype(jnp.bfloat16)
                * jnp.bfloat16(1.0 / _KV_SCALE))
    return x


def cache_spec(cfg: ModelConfig, batch: int, smax: int) -> Dict[str, Tuple]:
    """name -> (shape, dtype, logical axes)."""
    dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else jnp.bfloat16
    n_first = _n_dense_first(cfg)
    n_scan = cfg.num_layers - n_first
    out: Dict[str, Tuple] = {}
    if cfg.attention == "mla":
        def entry(prefix, lead=()):
            la = ("layers",) if lead else ()
            out[f"{prefix}ckv"] = ((*lead, batch, smax, cfg.kv_lora_rank), dt,
                                   (*la, "batch", "kv_seq", "kv_lora"))
            out[f"{prefix}kpe"] = ((*lead, batch, smax, cfg.qk_rope_head_dim),
                                   dt, (*la, "batch", "kv_seq", "qk_dim"))
    else:
        def entry(prefix, lead=()):
            la = ("layers",) if lead else ()
            shp = (*lead, batch, smax, cfg.num_kv_heads, cfg.head_dim)
            ax = (*la, "batch", "kv_seq", "kv_heads", "head_dim")
            out[f"{prefix}k"] = (shp, dt, ax)
            out[f"{prefix}v"] = (shp, dt, ax)
    for i in range(n_first):
        entry(f"layer{i}/")
    entry("scan/", lead=(n_scan,))
    out["pos"] = ((), jnp.int32, ())
    return out
