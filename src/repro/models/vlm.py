"""Llama-3.2-Vision backbone: decoder LM with gated cross-attention layers.

40 layers; every 5th layer (index % 5 == 4) is a gated cross-attention layer
attending to precomputed image patch embeddings (vision frontend is a STUB
per the task spec).  Scanned as 8 superblocks of [4 self + 1 cross].
Gates: x += tanh(g_attn) * xattn(...), x += tanh(g_mlp) * mlp(...), init 0.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import Registrar, maybe_scan, shard, subtree
from repro.models.transformer import (_Prefixed, _Stacked, _gqa_qkv, _remat)
from repro.models.encdec import cross_kv, cross_attend, _init_self_attn

F32 = jnp.float32


def _layout(cfg: ModelConfig):
    per = cfg.cross_attn_every
    n_super = cfg.num_layers // per
    assert cfg.num_layers % per == 0, "vlm layer count must divide pattern"
    return per, n_super


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(reg: Registrar, cfg: ModelConfig) -> None:
    per, n_super = _layout(cfg)
    L.init_embedding(reg, "embed", cfg.vocab_size, cfg.d_model)
    stk = _Stacked(reg, n_super, "sb/")
    for j in range(per - 1):
        sub = _Prefixed(stk, f"self{j}/")
        L.init_rmsnorm(sub, "ln_attn", cfg.d_model)
        _init_self_attn(sub, cfg)
        L.init_rmsnorm(sub, "ln_mlp", cfg.d_model)
        L.init_glu_mlp(sub, "mlp", cfg.d_model, cfg.d_ff)
    x = _Prefixed(stk, "cross/")
    L.init_rmsnorm(x, "ln_x", cfg.d_model)
    from repro.models.encdec import init_cross_attn
    init_cross_attn(x, cfg)
    x.param("gate_attn", (), (), init="zeros", dtype=F32)
    L.init_rmsnorm(x, "ln_mlp", cfg.d_model)
    L.init_glu_mlp(x, "mlp", cfg.d_model, cfg.d_ff)
    x.param("gate_mlp", (), (), init="zeros", dtype=F32)
    L.init_rmsnorm(reg, "ln_f", cfg.d_model)
    if not cfg.tie_embeddings:
        reg.param("head/w", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                  scale=cfg.d_model ** -0.5)


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------


def _self_layer(p, cfg, x, mode, cache_l=None, pos=None):
    new_cache = {}
    h = L.rmsnorm(p, "ln_attn", x, cfg.norm_eps)
    if mode in ("train", "prefill"):
        positions = jnp.arange(x.shape[1])[None, :]
        q, k, v = _gqa_qkv(p, cfg, h, positions)
        o = L.attention(q, k, v, causal=True, impl=cfg.attention_impl,
                        chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)
        if mode == "prefill":
            new_cache["k"], new_cache["v"] = k, v
        x = x + L.dense(p, "attn/wo", o, "...hk,hkd->...d")
    else:
        b = x.shape[0]
        posv = jnp.full((b,), pos)
        q = L.dense(p, "attn/wq", h, "...d,dhk->...hk")
        k = L.dense(p, "attn/wk", h, "...d,dhk->...hk")
        v = L.dense(p, "attn/wv", h, "...d,dhk->...hk")
        q = L.rope(q, posv[:, None], cfg.rope_theta)
        k = L.rope(k, posv[:, None], cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(cache_l["k"], k[:, None],
                                                 pos, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache_l["v"], v[:, None],
                                                 pos, 1)
        o = L.decode_attention(q, kc, vc, jnp.full((b,), pos + 1))
        x = x + L.dense(p, "attn/wo", o, "...hk,hkd->...d")
        new_cache["k"], new_cache["v"] = kc, vc
    h = L.rmsnorm(p, "ln_mlp", x, cfg.norm_eps)
    x = x + L.glu_mlp(p, "mlp", h, cfg.mlp_act)
    if x.ndim == 3:
        x = shard(x, "batch", "act_seq", "embed")
    return x, new_cache


def _cross_layer(p, cfg, x, img_embeds=None, xkv=None, mode="train"):
    new_cache = {}
    h = L.rmsnorm(p, "ln_x", x, cfg.norm_eps)
    if xkv is None:
        xk, xv = cross_kv(p, cfg, img_embeds)
        if mode == "prefill":
            new_cache["xk"], new_cache["xv"] = xk, xv
    else:
        xk, xv = xkv
    a = cross_attend(p, cfg, h, xk, xv)
    x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * a
    h = L.rmsnorm(p, "ln_mlp", x, cfg.norm_eps)
    m = L.glu_mlp(p, "mlp", h, cfg.mlp_act)
    x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * m
    if x.ndim == 3:
        x = shard(x, "batch", "act_seq", "embed")
    return x, new_cache


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------


def _superblock(p_sb, cfg, x, img_embeds, mode, cache_sb=None, pos=None):
    per, _ = _layout(cfg)
    caches = {}
    for j in range(per - 1):
        p_l = subtree(p_sb, f"self{j}/")
        c_l = subtree(cache_sb, f"self{j}/") if cache_sb else None
        x, c = _self_layer(p_l, cfg, x, mode, cache_l=c_l, pos=pos)
        for ck, cv in c.items():
            caches[f"self{j}/{ck}"] = cv
    p_x = subtree(p_sb, "cross/")
    if mode == "decode":
        c_x = subtree(cache_sb, "cross/")
        x, c = _cross_layer(p_x, cfg, x, xkv=(c_x["xk"], c_x["xv"]),
                            mode=mode)
        caches["cross/xk"], caches["cross/xv"] = c_x["xk"], c_x["xv"]
    else:
        x, c = _cross_layer(p_x, cfg, x, img_embeds=img_embeds, mode=mode)
        for ck, cv in c.items():
            caches[f"cross/{ck}"] = cv
    return x, caches


def forward_train(params, cfg: ModelConfig, tokens, image_embeds):
    img = shard(image_embeds.astype(cfg.activation_dtype),
                "batch", "img_seq", "embed")
    x = L.embed(params, "embed", tokens).astype(cfg.activation_dtype)
    x = shard(x, "batch", "seq", "embed")
    stacked = subtree(params, "sb/")

    def body(x, p_sb):
        fn = _remat(lambda pp, xx: _superblock(pp, cfg, xx, img, "train")[0],
                    cfg)
        return fn(p_sb, x), None

    x, _ = maybe_scan(body, x, stacked, cfg.scan_layers)
    x = L.rmsnorm(params, "ln_f", x, cfg.norm_eps)
    logits = L.logits_head(params, x,
                           None if cfg.tie_embeddings else "head", "embed")
    return logits, jnp.zeros((), F32)


def loss_fn(params, cfg: ModelConfig, batch):
    logits, _ = forward_train(params, cfg, batch["tokens"],
                              batch["image_embeds"])
    ce = L.softmax_xent(logits, batch["labels"], batch.get("mask"))
    return ce, {"ce": ce}


def prefill(params, cfg: ModelConfig, batch):
    img = batch["image_embeds"].astype(cfg.activation_dtype)
    x = L.embed(params, "embed", batch["tokens"]).astype(cfg.activation_dtype)
    stacked = subtree(params, "sb/")

    def body(x, p_sb):
        x, c = _superblock(p_sb, cfg, x, img, "prefill")
        return x, c

    x, caches = maybe_scan(body, x, stacked, cfg.scan_layers)
    x = L.rmsnorm(params, "ln_f", x, cfg.norm_eps)
    logits = L.logits_head(params, x[:, -1],
                           None if cfg.tie_embeddings else "head", "embed")
    cache = {f"sb/{k}": v for k, v in caches.items()}
    cache["pos"] = jnp.asarray(batch["tokens"].shape[1], jnp.int32)
    return cache, logits


def decode_step(params, cfg: ModelConfig, cache, tokens):
    pos = cache["pos"]
    x = L.embed(params, "embed", tokens).astype(cfg.activation_dtype)
    stacked = subtree(params, "sb/")
    sc = subtree(cache, "sb/")

    def body(x, xs):
        p_sb, c_sb = xs
        x, c = _superblock(p_sb, cfg, x, None, "decode", cache_sb=c_sb,
                           pos=pos)
        return x, c

    x, upd = maybe_scan(body, x, (stacked, sc), cfg.scan_layers)
    x = L.rmsnorm(params, "ln_f", x, cfg.norm_eps)
    logits = L.logits_head(params, x,
                           None if cfg.tie_embeddings else "head", "embed")
    new_cache = {f"sb/{k}": v for k, v in upd.items()}
    new_cache["pos"] = pos + 1
    return new_cache, logits


def cache_spec(cfg: ModelConfig, batch: int, smax: int) -> Dict[str, Tuple]:
    per, n_super = _layout(cfg)
    dt = jnp.bfloat16
    ax = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    out: Dict[str, Tuple] = {}
    for j in range(per - 1):
        shp = (n_super, batch, smax, cfg.num_kv_heads, cfg.head_dim)
        out[f"sb/self{j}/k"] = (shp, dt, ax)
        out[f"sb/self{j}/v"] = (shp, dt, ax)
    xshp = (n_super, batch, cfg.num_image_tokens, cfg.num_kv_heads,
            cfg.head_dim)
    out["sb/cross/xk"] = (xshp, dt, ax)
    out["sb/cross/xv"] = (xshp, dt, ax)
    out["pos"] = ((), jnp.int32, ())
    return out
