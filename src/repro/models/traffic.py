"""FENIX traffic classifiers (paper §6, §7.1 schemes a/b/d/e).

FENIX-CNN: embeddings -> 3 conv1d layers (64,128,256 filters, k=3, relu)
           -> global average pool -> FC 512 -> FC 256 -> classes.
FENIX-RNN: embeddings -> custom RNN cell (128 units, tanh) -> dense output.

Features are the paper's protocol-agnostic modality: sequences of packet
lengths and inter-packet delays (raw int32), bucketized into embedding ids
(the FPGA maps embeddings to LUTs, §5.2).  Float paths train; the quantized
INT8 path (quant/quantize.py) mirrors this structure layer-for-layer onto
the systolic GEMM kernel.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.fenix_models import TrafficModelConfig
from repro.models.param import Registrar

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Feature bucketization (integer-only; switch/FPGA friendly)
# ---------------------------------------------------------------------------


def bucketize(payload: jax.Array, cfg: TrafficModelConfig) -> jax.Array:
    """payload [..., T, 2] int32 (len, ipd_us) -> ids [..., T, 2] int32.

    len buckets: len >> 5 (32-byte granularity).  ipd buckets: 2 * floor
    log2(1+ipd) (logarithmic time bins).  Both clip to the table size.
    """
    ln = jnp.clip(payload[..., 0] >> 5, 0, cfg.len_buckets - 1)
    ipd = jnp.maximum(payload[..., 1], 0)
    lg = jnp.floor(jnp.log2(1.0 + ipd.astype(F32))).astype(jnp.int32)
    ip = jnp.clip(2 * lg, 0, cfg.ipd_buckets - 1)
    return jnp.stack([ln, ip], axis=-1)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(reg: Registrar, cfg: TrafficModelConfig) -> None:
    e = cfg.embed_dim
    reg.param("embed_len/table", (cfg.len_buckets, e), ("vocab", "embed"),
              scale=0.5, dtype=F32)
    reg.param("embed_ipd/table", (cfg.ipd_buckets, e), ("vocab", "embed"),
              scale=0.5, dtype=F32)
    d_in = 2 * e
    if cfg.kind == "cnn":
        c_prev = d_in
        for i, ch in enumerate(cfg.conv_filters):
            reg.param(f"conv{i}/w", (cfg.conv_kernel, c_prev, ch),
                      ("conv", "embed", "ffn"), scale=(cfg.conv_kernel
                                                       * c_prev) ** -0.5,
                      dtype=F32)
            reg.param(f"conv{i}/b", (ch,), ("ffn",), init="zeros", dtype=F32)
            c_prev = ch
        f_prev = c_prev
        for i, fc in enumerate(cfg.fc_dims):
            reg.param(f"fc{i}/w", (f_prev, fc), ("embed", "ffn"),
                      scale=f_prev ** -0.5, dtype=F32)
            reg.param(f"fc{i}/b", (fc,), ("ffn",), init="zeros", dtype=F32)
            f_prev = fc
        reg.param("head/w", (f_prev, cfg.num_classes), ("embed", "classes"),
                  scale=f_prev ** -0.5, dtype=F32)
        reg.param("head/b", (cfg.num_classes,), ("classes",), init="zeros",
                  dtype=F32)
    else:  # rnn
        u = cfg.rnn_units
        reg.param("cell/wx", (d_in, u), ("embed", "ffn"), scale=d_in ** -0.5,
                  dtype=F32)
        reg.param("cell/wh", (u, u), ("ffn", "ffn"), scale=u ** -0.5,
                  dtype=F32)
        reg.param("cell/b", (u,), ("ffn",), init="zeros", dtype=F32)
        reg.param("head/w", (u, cfg.num_classes), ("embed", "classes"),
                  scale=u ** -0.5, dtype=F32)
        reg.param("head/b", (cfg.num_classes,), ("classes",), init="zeros",
                  dtype=F32)


def init(cfg: TrafficModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    reg = Registrar(abstract=False, seed=seed, dtype=F32)
    init_params(reg, cfg)
    return reg.params


# ---------------------------------------------------------------------------
# Float forward (training / fp oracle)
# ---------------------------------------------------------------------------


def embed_ids(params: Dict, ids: jax.Array) -> jax.Array:
    """ids [..., T, 2] -> [..., T, 2E] float."""
    el = jnp.take(params["embed_len/table"], ids[..., 0], axis=0)
    ei = jnp.take(params["embed_ipd/table"], ids[..., 1], axis=0)
    return jnp.concatenate([el, ei], axis=-1)


def _conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """'same' conv1d via im2col (mirrors the int8 path exactly)."""
    k = w.shape[0]
    pad = k // 2
    s = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (pad, k - 1 - pad), (0, 0)))
    cols = jnp.stack([xp[:, i:i + s] for i in range(k)], axis=2)
    return jnp.einsum("bskc,kcf->bsf",
                      cols.reshape(*cols.shape[:2], k, -1), w) + b


def apply(params: Dict, cfg: TrafficModelConfig,
          payload: jax.Array) -> jax.Array:
    """payload [B,T,2] int32 -> logits [B,classes] (float path)."""
    ids = bucketize(payload, cfg)
    x = embed_ids(params, ids)                        # [B,T,2E]
    if cfg.kind == "cnn":
        for i in range(len(cfg.conv_filters)):
            x = jax.nn.relu(_conv1d(x, params[f"conv{i}/w"],
                                    params[f"conv{i}/b"]))
        x = jnp.mean(x, axis=1)                       # global average pool
        for i in range(len(cfg.fc_dims)):
            x = jax.nn.relu(x @ params[f"fc{i}/w"] + params[f"fc{i}/b"])
        return x @ params["head/w"] + params["head/b"]
    # rnn
    def cell(h, xt):
        h = jnp.tanh(xt @ params["cell/wx"] + h @ params["cell/wh"]
                     + params["cell/b"])
        return h, None

    h0 = jnp.zeros((x.shape[0], cfg.rnn_units), x.dtype)
    h, _ = jax.lax.scan(cell, h0, x.swapaxes(0, 1))
    return h @ params["head/w"] + params["head/b"]


def loss_fn(params: Dict, cfg: TrafficModelConfig, batch: Dict
            ) -> Tuple[jax.Array, Dict]:
    logits = apply(params, cfg, batch["payload"])
    labels = batch["label"]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    w = batch.get("weight")
    loss = jnp.mean(nll * w) if w is not None else jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(F32))
    return loss, {"acc": acc}
