"""Shared model layers: norms, RoPE, attention family, GLU MLP, MoE.

Attention implementations (selected by ``cfg.attention_impl``):

- ``naive``    — full [Sq,Skv] score matrix. Oracle for tests; O(S^2) memory.
- ``chunked``  — flash-style double scan over (q-chunk, kv-chunk) with online
                 softmax. O(S*chunk) memory but computes every block (2x causal
                 FLOP waste). The paper-faithful *baseline* for §Perf.
- ``bands``    — triangular band decomposition: band b computes blocks (i, i-b)
                 for all i>=b as one batched einsum, unrolled over bands, flash
                 merge across bands. Causal-optimal FLOPs, O(S*chunk) memory.
                 Also implements local-window attention by truncating the band
                 loop at window//chunk+1 bands (recurrentgemma, long_500k).

All softmax math in fp32; inputs/outputs in the activation dtype.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.param import Registrar, shard

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Norms / RoPE / embeddings
# ---------------------------------------------------------------------------


def init_rmsnorm(reg: Registrar, path: str, dim: int) -> None:
    reg.param(f"{path}/scale", (dim,), ("embed",), init="ones", dtype=F32)


def rmsnorm(params: Dict, path: str, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(F32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * params[f"{path}/scale"]
    return y.astype(dt)


def rmsnorm_1d(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head qk-norm (qwen3): normalize over the trailing dim."""
    dt = x.dtype
    x = x.astype(F32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Split-half rotary embedding. x [..., S, ..., D]; positions [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.arange(half, dtype=F32)
    inv = theta ** (-freq / half)                      # [half]
    ang = positions.astype(F32)[..., None] * inv       # [..., S, half]
    # broadcast ang to x's rank: x [..., S, H?, D] — add axes between S and D
    extra = x.ndim - ang.ndim - 1
    for _ in range(extra):
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def init_embedding(reg: Registrar, path: str, vocab: int, dim: int) -> None:
    reg.param(f"{path}/table", (vocab, dim), ("vocab", "embed"),
              init="normal", scale=0.02)


def embed(params: Dict, path: str, ids: jax.Array) -> jax.Array:
    table = params[f"{path}/table"]
    rows = jnp.take(table, ids, axis=0)
    s = params.get(f"{path}/table_scale")
    if s is not None:  # int8 serving table: dequantize the gathered rows
        rows = rows.astype(jnp.bfloat16) * s.astype(jnp.bfloat16)
    return rows


def W(params: Dict, key: str) -> jax.Array:
    """Fetch a matmul weight, dequantizing int8 serving weights on the fly.

    This is the LM-serving application of FENIX's Model Engine INT8 scheme:
    weights stored int8 with a per-tensor (per-layer when scanned) scale.
    """
    w = params[key]
    s = params.get(f"{key}_scale")
    if s is not None:
        w = w.astype(jnp.bfloat16) * s.astype(jnp.bfloat16)
    return w


def logits_head(params: Dict, x: jax.Array, head_path: Optional[str],
                embed_path: str) -> jax.Array:
    """x [..., d] -> [..., V]; tied variant reuses the embedding table."""
    if head_path is not None:
        w = W(params, f"{head_path}/w")                # [d, V]
        out = jnp.einsum("...d,dv->...v", x, w, preferred_element_type=F32)
    else:
        t = W(params, f"{embed_path}/table")           # [V, d]
        out = jnp.einsum("...d,vd->...v", x, t, preferred_element_type=F32)
    return shard(out, "batch", "seq", "vocab") if out.ndim == 3 else out


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean cross-entropy, fp32-stable; labels int [..., ]; logits [..., V]."""
    logits = logits.astype(F32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Attention core
# ---------------------------------------------------------------------------


def _pad_to(x: jax.Array, axis: int, mult: int) -> Tuple[jax.Array, int]:
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def _group(q: jax.Array, hkv: int) -> jax.Array:
    b, s, hq, d = q.shape
    return q.reshape(b, s, hkv, hq // hkv, d)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True,
              impl: str = "bands",
              chunk_q: int = 1024,
              chunk_kv: int = 1024,
              window: Optional[int] = None,
              kv_len: Optional[jax.Array] = None) -> jax.Array:
    """q [B,Sq,Hq,Dk]; k [B,Skv,Hkv,Dk]; v [B,Skv,Hkv,Dv] -> [B,Sq,Hq,Dv]."""
    b, sq, hq, dk = q.shape
    _, skv, hkv, _ = k.shape
    dv = v.shape[-1]
    scale = dk ** -0.5
    if impl == "naive":
        qg = _group(q, hkv)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=F32)
        s = s * scale
        qpos = jnp.arange(sq)[:, None] + (skv - sq if causal else 0)
        kpos = jnp.arange(skv)[None, :]
        mask = jnp.ones((sq, skv), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        if kv_len is not None:
            mask = mask[None] & (kpos[None] < kv_len[:, None, None])
            s = jnp.where(mask[:, None, None], s, -jnp.inf)
        else:
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhe->bqhge", p.astype(v.dtype), v)
        return o.reshape(b, sq, hq, dv)
    if impl == "chunked":
        return _chunked_attention(q, k, v, causal=causal, chunk_q=chunk_q,
                                  chunk_kv=chunk_kv, window=window,
                                  kv_len=kv_len, scale=scale)
    if impl == "bands":
        if not causal or sq != skv:
            # bands requires the square causal layout; use the unrolled
            # kv-block loop (no while op => exact cost_analysis flops)
            return _xblock_attention(q, k, v, causal=causal,
                                     chunk_kv=chunk_kv, window=window,
                                     kv_len=kv_len, scale=scale)
        return _band_attention(q, k, v, chunk=chunk_q, window=window,
                               scale=scale)
    raise ValueError(f"unknown attention impl {impl!r}")


def _xblock_attention(q, k, v, *, causal, chunk_kv, window, kv_len, scale):
    """Flash merge over an *unrolled* python loop of KV chunks.

    Used for cross/encoder attention: O(Sq*chunk) score memory, no while
    loops (cost_analysis counts every block).
    """
    b, sq, hq, dk = q.shape
    _, skv, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    ck = min(chunk_kv, skv)
    k, _ = _pad_to(k, 1, ck)
    v, _ = _pad_to(v, 1, ck)
    nk = k.shape[1] // ck
    qg = q.reshape(b, sq, hkv, g, dk)
    qpos = jnp.arange(sq)[:, None] + (skv - sq if causal else 0)
    m = jnp.full((b, hkv, g, sq), -jnp.inf, F32)
    lse = jnp.zeros((b, hkv, g, sq), F32)
    acc = jnp.zeros((b, hkv, g, sq, dv), F32)
    for ki in range(nk):
        kb = k[:, ki * ck:(ki + 1) * ck]
        vb = v[:, ki * ck:(ki + 1) * ck]
        kpos = ki * ck + jnp.arange(ck)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb,
                       preferred_element_type=F32) * scale
        msk = jnp.broadcast_to((kpos < skv)[None, :], (sq, ck))
        if causal:
            msk = msk & (kpos[None, :] <= qpos)
        if window is not None:
            msk = msk & ((qpos - kpos[None, :]) < window)
        if kv_len is not None:
            mskb = msk[None] & (kpos[None, None, :] < kv_len[:, None, None])
            s = jnp.where(mskb[:, None, None], s, -jnp.inf)
        else:
            s = jnp.where(msk[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isinf(s), 0.0, p)
        corr = jnp.exp(jnp.where(jnp.isinf(m), 0.0, m) - m_safe)
        corr = jnp.where(jnp.isinf(m), 0.0, corr)
        lse = lse * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] \
            + jnp.einsum("bhgqk,bkhe->bhgqe", p.astype(v.dtype), vb).astype(F32)
        m = m_new
    out = acc / jnp.maximum(lse, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, dv).astype(v.dtype)


def _chunked_attention(q, k, v, *, causal, chunk_q, chunk_kv, window, kv_len,
                       scale):
    b, sq, hq, dk = q.shape
    _, skv, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    cq = min(chunk_q, sq)
    ck = min(chunk_kv, skv)
    q, pq = _pad_to(q, 1, cq)
    k, pk = _pad_to(k, 1, ck)
    v, _ = _pad_to(v, 1, ck)
    nq, nk = q.shape[1] // cq, k.shape[1] // ck
    q_r = q.reshape(b, nq, cq, hkv, g, dk).transpose(1, 0, 2, 3, 4, 5)
    k_r = k.reshape(b, nk, ck, hkv, dk).transpose(1, 0, 2, 3, 4)
    v_r = v.reshape(b, nk, ck, hkv, dv).transpose(1, 0, 2, 3, 4)
    off = skv - sq if causal else 0
    eff_len = kv_len if kv_len is not None else jnp.full((b,), skv)

    def q_step(_, qc):
        qi, qb = qc                                   # [], [B,cq,hkv,g,dk]
        qpos = qi * cq + jnp.arange(cq) + off         # [cq]

        def kv_step(carry, kc):
            m, lse, acc = carry
            ki, kb, vb = kc
            kpos = ki * ck + jnp.arange(ck)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                           preferred_element_type=F32) * scale
            msk = jnp.ones((cq, ck), bool)
            if causal:
                msk &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                msk &= (qpos[:, None] - kpos[None, :]) < window
            msk = msk[None] & (kpos[None, None, :] < eff_len[:, None, None])
            s = jnp.where(msk[:, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isinf(s), 0.0, p)
            corr = jnp.exp(jnp.where(jnp.isinf(m), 0.0, m) - m_safe)
            corr = jnp.where(jnp.isinf(m), 0.0, corr)
            lse_new = lse * corr + jnp.sum(p, axis=-1)
            o = jnp.einsum("bhgqk,bkhe->bhgqe", p.astype(v.dtype), vb)
            acc_new = acc * corr[..., None] + o.astype(F32)
            return (m_new, lse_new, acc_new), None

        m0 = jnp.full((b, hkv, g, cq), -jnp.inf, F32)
        l0 = jnp.zeros((b, hkv, g, cq), F32)
        a0 = jnp.zeros((b, hkv, g, cq, dv), F32)
        (m, lse, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), k_r, v_r))
        out = acc / jnp.maximum(lse, 1e-30)[..., None]
        return None, out

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), q_r))
    # outs [nq, B, hkv, g, cq, dv] -> [B, S, Hq, dv]
    outs = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * cq, hq, dv)
    return outs[:, :sq].astype(v.dtype)


def _band_attention(q, k, v, *, chunk, window, scale):
    b, s, hq, dk = q.shape
    hkv = k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    c = min(chunk, s)
    q, pad = _pad_to(q, 1, c)
    k, _ = _pad_to(k, 1, c)
    v, _ = _pad_to(v, 1, c)
    sp = q.shape[1]
    n = sp // c
    q_r = q.reshape(b, n, c, hkv, g, dk)
    k_r = k.reshape(b, n, c, hkv, dk)
    v_r = v.reshape(b, n, c, hkv, dv)
    # band b touches offsets [b*c-(c-1), b*c+(c-1)]; include every band
    # whose minimum offset is still inside the window
    n_bands = n if window is None else min(n, (window + c - 2) // c + 1)

    m = jnp.full((b, n, hkv, g, c), -jnp.inf, F32)
    lse = jnp.zeros((b, n, hkv, g, c), F32)
    acc = jnp.zeros((b, n, hkv, g, c, dv), F32)
    qi_in = jnp.arange(c)[:, None]
    ki_in = jnp.arange(c)[None, :]
    valid_k = jnp.arange(sp) < s                       # kv padding mask

    for band in range(n_bands):
        nb = n - band
        qs = q_r[:, band:]                             # [B,nb,c,hkv,g,dk]
        ks = k_r[:, :nb]
        vs = v_r[:, :nb]
        sco = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qs, ks,
                         preferred_element_type=F32) * scale
        offs = band * c + qi_in - ki_in                # [c,c] distance q-k
        msk = offs >= 0
        if window is not None:
            msk &= offs < window
        kmask = valid_k[:nb * c].reshape(nb, c)        # [nb,c]
        full_mask = msk[None, None, None, None] & kmask[None, :, None, None, None, :]
        sco = jnp.where(full_mask, sco, -jnp.inf)
        m_old = m[:, band:]
        m_new = jnp.maximum(m_old, jnp.max(sco, axis=-1))
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(sco - m_safe[..., None])
        p = jnp.where(jnp.isinf(sco), 0.0, p)
        corr = jnp.exp(jnp.where(jnp.isinf(m_old), 0.0, m_old) - m_safe)
        corr = jnp.where(jnp.isinf(m_old), 0.0, corr)
        lse = lse.at[:, band:].set(lse[:, band:] * corr
                                   + jnp.sum(p, axis=-1))
        o = jnp.einsum("bnhgqk,bnkhe->bnhgqe", p.astype(v.dtype), vs)
        acc = acc.at[:, band:].set(acc[:, band:] * corr[..., None] + o.astype(F32))
        m = m.at[:, band:].set(m_new)

    out = acc / jnp.maximum(lse, 1e-30)[..., None]
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(b, sp, hq, dv)
    return out[:, :s].astype(v.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array,
                     window: Optional[int] = None) -> jax.Array:
    """Single-token attention. q [B,Hq,Dk]; caches [B,Smax,Hkv,D*]; lengths [B].

    The KV cache is annotated with kv_seq sharding (sequence-sharded decode):
    softmax partial reductions over the sharded axis become the measured
    all-reduces in the roofline.
    """
    b, hq, dk = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    dv = v_cache.shape[-1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, dk)
    k_cache = shard(k_cache, "batch", "kv_seq", "kv_heads", "head_dim")
    v_cache = shard(v_cache, "batch", "kv_seq", "kv_heads", "head_dim")
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=F32) * (dk ** -0.5)
    kpos = jnp.arange(smax)[None, :]
    mask = kpos < lengths[:, None]
    if window is not None:
        mask &= kpos > (lengths[:, None] - 1 - window)
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshe->bhge", p.astype(v_cache.dtype), v_cache)
    return o.reshape(b, hq, dv)


# ---------------------------------------------------------------------------
# Dense projections / MLP
# ---------------------------------------------------------------------------


def init_dense(reg: Registrar, path: str, shape, axes, bias: bool = False,
               bias_axes=None, scale: Optional[float] = None) -> None:
    reg.param(f"{path}/w", shape, axes, init="normal", scale=scale)
    if bias:
        bshape = shape[len(shape) - len(bias_axes):] if bias_axes else (shape[-1],)
        reg.param(f"{path}/b", bshape, bias_axes or (axes[-1],), init="zeros")


def dense(params: Dict, path: str, x: jax.Array, eq: str) -> jax.Array:
    y = jnp.einsum(eq, x, W(params, f"{path}/w"))
    if f"{path}/b" in params:
        y = y + params[f"{path}/b"]
    return y


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def init_glu_mlp(reg: Registrar, path: str, d: int, f: int,
                 stack: Tuple[int, ...] = ()) -> None:
    sa = tuple("stack" for _ in stack)
    reg.param(f"{path}/wi_gate", (*stack, d, f), (*sa, "embed", "ffn"),
              init="normal", scale=d ** -0.5)
    reg.param(f"{path}/wi_up", (*stack, d, f), (*sa, "embed", "ffn"),
              init="normal", scale=d ** -0.5)
    reg.param(f"{path}/wo", (*stack, f, d), (*sa, "ffn", "embed"),
              init="normal", scale=f ** -0.5)


def glu_mlp(params: Dict, path: str, x: jax.Array, act: str) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, W(params, f"{path}/wi_gate"))
    u = jnp.einsum("...d,df->...f", x, W(params, f"{path}/wi_up"))
    h = _act(act, g) * u
    if h.ndim == 3:
        h = shard(h, "batch", "seq", "ffn")
    return jnp.einsum("...f,fd->...d", h, W(params, f"{path}/wo"))


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based capacity dispatch)
# ---------------------------------------------------------------------------


def init_moe(reg: Registrar, path: str, d: int, moe) -> None:
    e, f = moe.num_experts, moe.expert_d_ff
    reg.param(f"{path}/router/w", (d, e), ("embed", "experts"),
              init="normal", scale=d ** -0.5, dtype=F32)
    for nm in ("wi_gate", "wi_up"):
        reg.param(f"{path}/experts/{nm}", (e, d, f),
                  ("experts", "embed", "ffn"), init="normal", scale=d ** -0.5)
    reg.param(f"{path}/experts/wo", (e, f, d), ("experts", "ffn", "embed"),
              init="normal", scale=f ** -0.5)
    if moe.num_shared_experts:
        init_glu_mlp(reg, f"{path}/shared", d, moe.shared_d_ff)
        if moe.shared_gated:
            reg.param(f"{path}/shared_gate/w", (d, 1), ("embed", "classes"),
                      init="normal", scale=d ** -0.5)


def moe_ffn(params: Dict, path: str, x: jax.Array, moe, act: str
            ) -> Tuple[jax.Array, jax.Array]:
    """x [B,S,d] -> (y [B,S,d], aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    e, k = moe.num_experts, moe.top_k
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(F32), params[f"{path}/router/w"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                  # [t,k]
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_i, e, dtype=F32).sum(1), axis=0)  # [e]
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs) * moe.aux_loss_weight

    cap = max(1, int(moe.capacity_factor * t * k / e))
    flat_e = top_i.reshape(-1)                              # [t*k]
    sort_idx = jnp.argsort(flat_e)
    sorted_e = flat_e[sort_idx]
    token_of = sort_idx // k
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(t * k) - starts[sorted_e]
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # OOB => drop

    token_of = shard(token_of, "moe_tokens")
    slot = shard(slot, "moe_tokens")
    buf = shard(jnp.zeros((e * cap, d), x.dtype), "moe_flat", "embed")
    # chunked dispatch bounds the replicated gather working set to
    # (t*k/chunks, d): GSPMD materializes gathers with computed indices
    # replicated, so the chunk count is a direct memory lever (§Perf).
    nc = max(1, int(moe.dispatch_chunks))
    csz = (t * k + nc - 1) // nc
    xf_g = xf
    if nc > 1:
        # one explicit all-gather of the token matrix per layer (~d*T bf16)
        # beats GSPMD's permute-chain lowering of sharded computed-index
        # gathers by ~2 orders of magnitude in moved bytes (§Perf A8)
        from repro.models.param import replicate
        xf_g = replicate(xf)
    for ci in range(nc):
        sl = slice(ci * csz, min((ci + 1) * csz, t * k))
        g_c = shard(xf_g[token_of[sl]], "moe_tokens", "embed")
        buf = buf.at[slot[sl]].set(g_c, mode="drop")
    # flat rows are grouped by expert, so row-sharding == expert-sharding
    buf = shard(buf, "moe_flat", "embed")
    buf = buf.reshape(e, cap, d)
    buf = shard(buf, "experts", "expert_cap", "embed")

    g = jnp.einsum("ecd,edf->ecf", buf, W(params, f"{path}/experts/wi_gate"))
    u = jnp.einsum("ecd,edf->ecf", buf, W(params, f"{path}/experts/wi_up"))
    h = _act(act, g) * u
    h = shard(h, "experts", "expert_cap", "ffn")
    y_e = jnp.einsum("ecf,efd->ecd", h, W(params, f"{path}/experts/wo"))
    y_e = shard(y_e, "experts", "expert_cap", "embed")

    y_flat = shard(y_e.reshape(e * cap, d), "moe_flat", "embed")
    w = jnp.where(keep, top_w.reshape(-1)[sort_idx], 0.0)   # [t*k]
    y = shard(jnp.zeros((t, d), x.dtype), "moe_tokens", "embed")
    # combine mirrors the chunked dispatch: gather expert outputs in
    # replicated chunks (local masked gather), scatter-add into the
    # token-sharded accumulator (local masked scatter) — avoids GSPMD's
    # mask+all-reduce lowering of computed-index gathers (§Perf A6/A7)
    for ci in range(nc):
        sl = slice(ci * csz, min((ci + 1) * csz, t * k))
        c_c = jnp.take(y_flat, slot[sl], axis=0, mode="fill",
                       fill_value=0) * w[sl, None].astype(x.dtype)
        y = y.at[token_of[sl]].add(c_c)
    y = shard(y, "moe_tokens", "embed")

    if moe.num_shared_experts:
        sh = glu_mlp(params, f"{path}/shared", xf, act)
        if moe.shared_gated:
            gate = jax.nn.sigmoid(
                jnp.einsum("td,dz->tz", xf, params[f"{path}/shared_gate/w"]))
            sh = sh * gate.astype(x.dtype)
        y = y + sh
    return y.reshape(b, s, d), aux
