"""Encoder-decoder transformer backbone (seamless-m4t-medium).

The audio/speech frontend is a STUB per the task spec: the encoder consumes
precomputed frame embeddings [B, S_src, d_model] from ``input_specs()``.
Decoder: causal self-attention (KV-cached) + cross-attention to the encoder
output (cross-KV computed once at prefill).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import Registrar, maybe_scan, shard, subtree
from repro.models.transformer import _Stacked, _gqa_qkv, _remat

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_self_attn(reg, cfg: ModelConfig, path="attn") -> None:
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    reg.param(f"{path}/wq/w", (d, h, dh), ("embed", "heads", "head_dim"),
              scale=d ** -0.5)
    reg.param(f"{path}/wk/w", (d, hkv, dh), ("embed", "kv_heads", "head_dim"),
              scale=d ** -0.5)
    reg.param(f"{path}/wv/w", (d, hkv, dh), ("embed", "kv_heads", "head_dim"),
              scale=d ** -0.5)
    reg.param(f"{path}/wo/w", (h, dh, d), ("heads", "head_dim", "embed"),
              scale=(h * dh) ** -0.5)


def init_cross_attn(reg, cfg: ModelConfig, path="xattn") -> None:
    _init_self_attn(reg, cfg, path=path)


def init_params(reg: Registrar, cfg: ModelConfig) -> None:
    L.init_embedding(reg, "embed", cfg.vocab_size, cfg.d_model)
    enc = _Stacked(reg, cfg.num_encoder_layers, "enc/")
    L.init_rmsnorm(enc, "ln_attn", cfg.d_model)
    _init_self_attn(enc, cfg)
    L.init_rmsnorm(enc, "ln_mlp", cfg.d_model)
    L.init_glu_mlp(enc, "mlp", cfg.d_model, cfg.d_ff)
    dec = _Stacked(reg, cfg.num_decoder_layers, "dec/")
    L.init_rmsnorm(dec, "ln_attn", cfg.d_model)
    _init_self_attn(dec, cfg)
    L.init_rmsnorm(dec, "ln_x", cfg.d_model)
    init_cross_attn(dec, cfg)
    L.init_rmsnorm(dec, "ln_mlp", cfg.d_model)
    L.init_glu_mlp(dec, "mlp", cfg.d_model, cfg.d_ff)
    L.init_rmsnorm(reg, "ln_enc_f", cfg.d_model)
    L.init_rmsnorm(reg, "ln_f", cfg.d_model)
    if not cfg.tie_embeddings:
        reg.param("head/w", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                  scale=cfg.d_model ** -0.5)


# ---------------------------------------------------------------------------
# Cross attention
# ---------------------------------------------------------------------------


def cross_kv(p, cfg: ModelConfig, ctx: jax.Array, path="xattn"):
    """ctx [B,Sk,d] -> (k, v) [B,Sk,hkv,dh]. No rope on cross keys."""
    k = L.dense(p, f"{path}/wk", ctx, "...d,dhk->...hk")
    v = L.dense(p, f"{path}/wv", ctx, "...d,dhk->...hk")
    return k, v


def cross_attend(p, cfg: ModelConfig, x, k, v, path="xattn"):
    """x [B,Sq,d] or [B,d]; full (non-causal) attention to ctx."""
    q = L.dense(p, f"{path}/wq", x, "...d,dhk->...hk")
    if x.ndim == 2:
        lengths = jnp.full((x.shape[0],), k.shape[1])
        o = L.decode_attention(q, k, v, lengths)
    else:
        o = L.attention(q, k, v, causal=False, impl=cfg.attention_impl,
                        chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)
    return L.dense(p, f"{path}/wo", o, "...hk,hkd->...d")


# ---------------------------------------------------------------------------
# Encoder / decoder layers
# ---------------------------------------------------------------------------


def _enc_layer(p, cfg, x):
    h = L.rmsnorm(p, "ln_attn", x, cfg.norm_eps)
    positions = jnp.arange(x.shape[1])[None, :]
    q, k, v = _gqa_qkv(p, cfg, h, positions)
    o = L.attention(q, k, v, causal=False, impl=cfg.attention_impl,
                    chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)
    x = x + L.dense(p, "attn/wo", o, "...hk,hkd->...d")
    h = L.rmsnorm(p, "ln_mlp", x, cfg.norm_eps)
    x = x + L.glu_mlp(p, "mlp", h, cfg.mlp_act)
    return shard(x, "batch", "act_seq", "embed")


def encode(params, cfg: ModelConfig, src_embeds: jax.Array) -> jax.Array:
    x = shard(src_embeds.astype(cfg.activation_dtype), "batch", "seq", "embed")
    stacked = subtree(params, "enc/")

    def body(x, p_l):
        fn = _remat(lambda pp, xx: _enc_layer(pp, cfg, xx), cfg)
        return fn(p_l, x), None

    x, _ = maybe_scan(body, x, stacked, cfg.scan_layers)
    return L.rmsnorm(params, "ln_enc_f", x, cfg.norm_eps)


def _dec_layer(p, cfg, x, enc_out=None, xkv=None, mode="train",
               cache_l=None, pos=None):
    """Returns (x, cache_entry)."""
    new_cache = {}
    h = L.rmsnorm(p, "ln_attn", x, cfg.norm_eps)
    if mode in ("train", "prefill"):
        positions = jnp.arange(x.shape[1])[None, :]
        q, k, v = _gqa_qkv(p, cfg, h, positions)
        o = L.attention(q, k, v, causal=True, impl=cfg.attention_impl,
                        chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)
        if mode == "prefill":
            new_cache["k"], new_cache["v"] = k, v
        x = x + L.dense(p, "attn/wo", o, "...hk,hkd->...d")
    else:
        b = x.shape[0]
        posv = jnp.full((b,), pos)
        q = L.dense(p, "attn/wq", h, "...d,dhk->...hk")
        k = L.dense(p, "attn/wk", h, "...d,dhk->...hk")
        v = L.dense(p, "attn/wv", h, "...d,dhk->...hk")
        q = L.rope(q, posv[:, None], cfg.rope_theta)
        k = L.rope(k, posv[:, None], cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(cache_l["k"], k[:, None],
                                                 pos, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache_l["v"], v[:, None],
                                                 pos, 1)
        o = L.decode_attention(q, kc, vc, jnp.full((b,), pos + 1))
        x = x + L.dense(p, "attn/wo", o, "...hk,hkd->...d")
        new_cache["k"], new_cache["v"] = kc, vc
    # cross attention
    h = L.rmsnorm(p, "ln_x", x, cfg.norm_eps)
    if xkv is None:
        xk, xv = cross_kv(p, cfg, enc_out)
        if mode == "prefill":
            new_cache["xk"], new_cache["xv"] = xk, xv
    else:
        xk, xv = xkv
    x = x + cross_attend(p, cfg, h, xk, xv)
    h = L.rmsnorm(p, "ln_mlp", x, cfg.norm_eps)
    x = x + L.glu_mlp(p, "mlp", h, cfg.mlp_act)
    if x.ndim == 3:
        x = shard(x, "batch", "act_seq", "embed")
    return x, new_cache


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------


def loss_fn(params, cfg: ModelConfig, batch):
    enc_out = encode(params, cfg, batch["src_embeds"])
    x = L.embed(params, "embed", batch["tokens"]).astype(cfg.activation_dtype)
    x = shard(x, "batch", "seq", "embed")
    stacked = subtree(params, "dec/")

    def body(x, p_l):
        fn = _remat(lambda pp, xx: _dec_layer(pp, cfg, xx, enc_out=enc_out,
                                              mode="train")[0], cfg)
        return fn(p_l, x), None

    x, _ = maybe_scan(body, x, stacked, cfg.scan_layers)
    x = L.rmsnorm(params, "ln_f", x, cfg.norm_eps)
    logits = L.logits_head(params, x,
                           None if cfg.tie_embeddings else "head", "embed")
    ce = L.softmax_xent(logits, batch["labels"], batch.get("mask"))
    return ce, {"ce": ce}


def prefill(params, cfg: ModelConfig, batch):
    """batch: src_embeds [B,Ss,d], tokens [B,St]. Returns (cache, logits)."""
    enc_out = encode(params, cfg, batch["src_embeds"])
    x = L.embed(params, "embed", batch["tokens"]).astype(cfg.activation_dtype)
    stacked = subtree(params, "dec/")

    def body(x, p_l):
        x, c = _dec_layer(p_l, cfg, x, enc_out=enc_out, mode="prefill")
        return x, c

    x, caches = maybe_scan(body, x, stacked, cfg.scan_layers)
    x = L.rmsnorm(params, "ln_f", x, cfg.norm_eps)
    logits = L.logits_head(params, x[:, -1],
                           None if cfg.tie_embeddings else "head", "embed")
    cache = {f"dec/{k}": v for k, v in caches.items()}
    cache["pos"] = jnp.asarray(batch["tokens"].shape[1], jnp.int32)
    return cache, logits


def decode_step(params, cfg: ModelConfig, cache, tokens):
    pos = cache["pos"]
    x = L.embed(params, "embed", tokens).astype(cfg.activation_dtype)
    stacked = subtree(params, "dec/")
    dc = subtree(cache, "dec/")

    def body(x, xs):
        p_l, c_l = xs
        x, c = _dec_layer(p_l, cfg, x, xkv=(c_l["xk"], c_l["xv"]),
                          mode="decode", cache_l=c_l, pos=pos)
        c["xk"], c["xv"] = c_l["xk"], c_l["xv"]
        return x, c

    x, upd = maybe_scan(body, x, (stacked, dc), cfg.scan_layers)
    x = L.rmsnorm(params, "ln_f", x, cfg.norm_eps)
    logits = L.logits_head(params, x,
                           None if cfg.tie_embeddings else "head", "embed")
    new_cache = {f"dec/{k}": v for k, v in upd.items()}
    new_cache["pos"] = pos + 1
    return new_cache, logits


def cache_spec(cfg: ModelConfig, batch: int, smax: int,
               src_len: int) -> Dict[str, Tuple]:
    dt = jnp.bfloat16
    ll = cfg.num_decoder_layers
    kv = (ll, batch, smax, cfg.num_kv_heads, cfg.head_dim)
    xkv = (ll, batch, src_len, cfg.num_kv_heads, cfg.head_dim)
    ax = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return {
        "dec/k": (kv, dt, ax), "dec/v": (kv, dt, ax),
        "dec/xk": (xkv, dt, ax), "dec/xv": (xkv, dt, ax),
        "pos": ((), jnp.int32, ()),
    }
