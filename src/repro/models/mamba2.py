"""Mamba2 (SSD — state-space duality) LM. Attention-free; sub-quadratic.

Chunked SSD algorithm (Dao & Gu 2024, arXiv:2405.21060):
  within-chunk quadratic term (diagonal blocks of the semiseparable matrix)
  + inter-chunk low-rank term carried by a sequential scan over chunk states.

Train/prefill cost: O(S * Q) attention-free; decode: O(1) state update.
State per layer: conv tail [B, d_conv-1, conv_dim] + SSM state [B, H, P, N].
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import Registrar, maybe_scan, shard, subtree
from repro.models.transformer import _Stacked, _remat

F32 = jnp.float32


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return d_in, n_heads, conv_dim


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(reg, cfg: ModelConfig) -> None:
    d = cfg.d_model
    s = cfg.ssm
    d_in, h, conv_dim = _dims(cfg)
    gn = s.n_groups * s.d_state
    L.init_rmsnorm(reg, "ln", d)
    reg.param("wz/w", (d, d_in), ("embed", "ssm_inner"), scale=d ** -0.5)
    reg.param("wx/w", (d, d_in), ("embed", "ssm_inner"), scale=d ** -0.5)
    reg.param("wb/w", (d, gn), ("embed", "state"), scale=d ** -0.5)
    reg.param("wc/w", (d, gn), ("embed", "state"), scale=d ** -0.5)
    reg.param("wdt/w", (d, h), ("embed", "ssm_heads"), scale=d ** -0.5)
    reg.param("conv/w", (s.d_conv, conv_dim), ("conv", "ssm_inner"),
              init="normal", scale=s.d_conv ** -0.5)
    reg.param("conv/b", (conv_dim,), ("ssm_inner",), init="zeros")
    reg.param("A_log", (h,), ("ssm_heads",), init="uniform", scale=1.0,
              dtype=F32)
    reg.param("D", (h,), ("ssm_heads",), init="ones", dtype=F32)
    reg.param("dt_bias", (h,), ("ssm_heads",), init="zeros", dtype=F32)
    reg.param("gnorm/scale", (d_in,), ("ssm_inner",), init="ones", dtype=F32)
    reg.param("wo/w", (d_in, d), ("ssm_inner", "embed"), scale=d_in ** -0.5)


def init_params(reg: Registrar, cfg: ModelConfig) -> None:
    L.init_embedding(reg, "embed", cfg.vocab_size, cfg.d_model)
    _init_block(_Stacked(reg, cfg.num_layers, "layers/"), cfg)
    L.init_rmsnorm(reg, "ln_f", cfg.d_model)
    if not cfg.tie_embeddings:
        reg.param("head/w", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                  scale=cfg.d_model ** -0.5)


# ---------------------------------------------------------------------------
# Core SSD math
# ---------------------------------------------------------------------------


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d: x [B,S,C]; w [K,C]. O(K) shifted adds."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    s = x.shape[1]
    y = sum(xp[:, j:j + s] * w[j] for j in range(k))
    return y + b


def _ssd_chunked(xdt, dA, b_r, c_r, cfg: ModelConfig, h0=None):
    """Chunked SSD.

    xdt [B,S,G,R,P] (dt-scaled inputs), dA [B,S,G,R] (log decay),
    b_r/c_r [B,S,G,N].  Returns (y [B,S,G,R,P], h_last [B,G,R,P,N]).
    """
    bsz, s, g, r, p = xdt.shape
    n = b_r.shape[-1]
    q = min(cfg.ssm.chunk_size, s)
    pad = (-s) % q
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_r = jnp.pad(b_r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_r = jnp.pad(c_r, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // q
    xdt = xdt.reshape(bsz, nc, q, g, r, p)
    dA = dA.reshape(bsz, nc, q, g, r)
    b_c = b_r.reshape(bsz, nc, q, g, n)
    c_c = c_r.reshape(bsz, nc, q, g, n)

    a_cs = jnp.cumsum(dA, axis=2)                     # [B,nc,Q,G,R]
    # within-chunk (diagonal) term
    scores = jnp.einsum("bclgn,bcsgn->bcgls", c_c, b_c,
                        preferred_element_type=F32)   # [B,nc,G,Q,Q]
    decay = a_cs[:, :, :, None] - a_cs[:, :, None]    # [B,nc,Ql,Qs,G,R]
    decay = decay.transpose(0, 1, 4, 5, 2, 3)         # [B,nc,G,R,Ql,Qs]
    mask = jnp.tril(jnp.ones((q, q), bool))
    lmat = jnp.where(mask, jnp.exp(decay), 0.0)
    st = scores[:, :, :, None] * lmat                 # [B,nc,G,R,Ql,Qs]
    y_diag = jnp.einsum("bcgrls,bcsgrp->bclgrp", st.astype(xdt.dtype), xdt)

    # chunk states
    dstate = jnp.exp(a_cs[:, :, -1:, :, :] - a_cs)    # [B,nc,Q,G,R]
    xw = xdt * dstate[..., None].astype(xdt.dtype)
    states = jnp.einsum("bcsgn,bcsgrp->bcgrpn", b_c, xw)  # [B,nc,G,R,P,N]

    # inter-chunk sequential scan
    a_sum = a_cs[:, :, -1]                            # [B,nc,G,R]

    def step(h, xs):
        st_c, dec_c = xs                              # [B,G,R,P,N], [B,G,R]
        h_new = h * jnp.exp(dec_c)[..., None, None].astype(h.dtype) + st_c
        return h_new, h                               # emit h_prev

    if h0 is None:
        h0 = jnp.zeros((bsz, g, r, p, n), F32)
    h_last, h_prev = jax.lax.scan(
        step, h0, (states.astype(F32).transpose(1, 0, 2, 3, 4, 5),
                   a_sum.transpose(1, 0, 2, 3)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4, 5)       # [B,nc,G,R,P,N]

    decay_in = jnp.exp(a_cs)                          # [B,nc,Q,G,R]
    y_off = jnp.einsum("bclgn,bcgrpn,bclgr->bclgrp", c_c,
                       h_prev.astype(xdt.dtype),
                       decay_in.astype(xdt.dtype))
    y = (y_diag + y_off).reshape(bsz, sp, g, r, p)[:, :s]
    return y, h_last


def _block_seq(p, cfg: ModelConfig, x, h0=None, conv0=None):
    """Full-sequence block. x [B,S,d] -> (y, (conv_tail, h_last))."""
    s_cfg = cfg.ssm
    d_in, h, conv_dim = _dims(cfg)
    g, r = s_cfg.n_groups, (d_in // s_cfg.head_dim) // s_cfg.n_groups
    pdim, n = s_cfg.head_dim, s_cfg.d_state
    bsz, s, _ = x.shape
    hx = L.rmsnorm(p, "ln", x, cfg.norm_eps)
    z = L.dense(p, "wz", hx, "...d,di->...i")
    xbc = jnp.concatenate([
        L.dense(p, "wx", hx, "...d,di->...i"),
        L.dense(p, "wb", hx, "...d,di->...i"),
        L.dense(p, "wc", hx, "...d,di->...i")], axis=-1)
    if conv0 is not None:
        xbc_in = jnp.concatenate([conv0, xbc], axis=1)
        conv_tail = xbc_in[:, -(s_cfg.d_conv - 1):]
        y = _causal_conv(xbc_in, p["conv/w"], p["conv/b"])[:, -s:]
    else:
        conv_tail = xbc[:, max(0, s - (s_cfg.d_conv - 1)):]
        if conv_tail.shape[1] < s_cfg.d_conv - 1:
            conv_tail = jnp.pad(
                conv_tail,
                ((0, 0), (s_cfg.d_conv - 1 - conv_tail.shape[1], 0), (0, 0)))
        y = _causal_conv(xbc, p["conv/w"], p["conv/b"])
    y = jax.nn.silu(y)
    xs, bs, cs = jnp.split(y, [d_in, d_in + g * n], axis=-1)
    dt = jax.nn.softplus(
        L.dense(p, "wdt", hx, "...d,dh->...h").astype(F32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])                          # [H]
    dA = (dt * a).reshape(bsz, s, g, r)
    xs = xs.reshape(bsz, s, g, r, pdim)
    xs = shard(xs, "batch", "seq", "groups", "ssm_heads", "head_dim")
    xdt = xs * dt.reshape(bsz, s, g, r)[..., None].astype(xs.dtype)
    b_r = bs.reshape(bsz, s, g, n)
    c_r = cs.reshape(bsz, s, g, n)
    yss, h_last = _ssd_chunked(xdt, dA, b_r, c_r, cfg, h0=h0)
    yss = yss + xs * p["D"].reshape(g, r)[..., None].astype(xs.dtype)
    yf = yss.reshape(bsz, s, d_in)
    yf = L.rmsnorm_1d(p["gnorm/scale"], yf * jax.nn.silu(z), cfg.norm_eps)
    out = L.dense(p, "wo", yf, "...i,id->...d")
    return shard(x + out, "batch", "act_seq", "embed"), (conv_tail, h_last)


def _block_decode(p, cfg: ModelConfig, x, conv_state, h_state):
    """Single-token step. x [B,d]; conv_state [B,K-1,C]; h [B,G,R,P,N]."""
    s_cfg = cfg.ssm
    d_in, h, conv_dim = _dims(cfg)
    g, r = s_cfg.n_groups, (d_in // s_cfg.head_dim) // s_cfg.n_groups
    pdim, n = s_cfg.head_dim, s_cfg.d_state
    bsz = x.shape[0]
    hx = L.rmsnorm(p, "ln", x, cfg.norm_eps)
    z = L.dense(p, "wz", hx, "...d,di->...i")
    xbc = jnp.concatenate([
        L.dense(p, "wx", hx, "...d,di->...i"),
        L.dense(p, "wb", hx, "...d,di->...i"),
        L.dense(p, "wc", hx, "...d,di->...i")], axis=-1)  # [B,C]
    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)  # [B,K,C]
    y = jnp.einsum("bkc,kc->bc", window, p["conv/w"]) + p["conv/b"]
    y = jax.nn.silu(y)
    new_conv = window[:, 1:]
    xs, bs, cs = jnp.split(y, [d_in, d_in + g * n], axis=-1)
    dt = jax.nn.softplus(
        L.dense(p, "wdt", hx, "...d,dh->...h").astype(F32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    dA = (dt * a).reshape(bsz, g, r)
    xs = xs.reshape(bsz, g, r, pdim)
    b_r = bs.reshape(bsz, g, n)
    c_r = cs.reshape(bsz, g, n)
    xdt = (xs.astype(F32) * dt.reshape(bsz, g, r)[..., None])
    h_new = (h_state * jnp.exp(dA)[..., None, None]
             + jnp.einsum("bgn,bgrp->bgrpn", b_r.astype(F32), xdt))
    y_t = jnp.einsum("bgn,bgrpn->bgrp", c_r.astype(F32), h_new)
    y_t = y_t + xs.astype(F32) * p["D"].reshape(g, r)[..., None]
    yf = y_t.reshape(bsz, d_in).astype(x.dtype)
    yf = L.rmsnorm_1d(p["gnorm/scale"], yf * jax.nn.silu(z), cfg.norm_eps)
    out = L.dense(p, "wo", yf, "...i,id->...d")
    return x + out, (new_conv, h_new)


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------


def forward_train(params: Dict, cfg: ModelConfig, tokens: jax.Array):
    x = L.embed(params, "embed", tokens).astype(cfg.activation_dtype)
    x = shard(x, "batch", "seq", "embed")
    stacked = subtree(params, "layers/")

    def body(x, p_l):
        fn = _remat(lambda pp, xx: _block_seq(pp, cfg, xx)[0], cfg)
        return fn(p_l, x), None

    x, _ = maybe_scan(body, x, stacked, cfg.scan_layers)
    x = L.rmsnorm(params, "ln_f", x, cfg.norm_eps)
    logits = L.logits_head(params, x,
                           None if cfg.tie_embeddings else "head", "embed")
    return logits, jnp.zeros((), F32)


def loss_fn(params, cfg, batch):
    logits, _ = forward_train(params, cfg, batch["tokens"])
    ce = L.softmax_xent(logits, batch["labels"], batch.get("mask"))
    return ce, {"ce": ce}


def prefill(params: Dict, cfg: ModelConfig, tokens: jax.Array):
    x = L.embed(params, "embed", tokens).astype(cfg.activation_dtype)
    stacked = subtree(params, "layers/")

    def body(x, p_l):
        x, (conv_t, h_last) = _block_seq(p_l, cfg, x)
        return x, {"conv": conv_t, "h": h_last}

    x, caches = maybe_scan(body, x, stacked, cfg.scan_layers)
    x = L.rmsnorm(params, "ln_f", x, cfg.norm_eps)
    logits = L.logits_head(params, x[:, -1],
                           None if cfg.tie_embeddings else "head", "embed")
    cache = {f"scan/{k}": v for k, v in caches.items()}
    cache["pos"] = jnp.asarray(tokens.shape[1], jnp.int32)
    return cache, logits


def decode_step(params: Dict, cfg: ModelConfig, cache: Dict, tokens: jax.Array):
    x = L.embed(params, "embed", tokens).astype(cfg.activation_dtype)
    stacked = subtree(params, "layers/")
    sc = {k[len("scan/"):]: v for k, v in cache.items() if k.startswith("scan/")}

    def body(x, xs):
        p_l, conv_s, h_s = xs
        x, (c2, h2) = _block_decode(p_l, cfg, x, conv_s, h_s)
        return x, {"conv": c2, "h": h2}

    x, upd = maybe_scan(body, x, (stacked, sc["conv"], sc["h"]),
                        cfg.scan_layers)
    x = L.rmsnorm(params, "ln_f", x, cfg.norm_eps)
    logits = L.logits_head(params, x,
                           None if cfg.tie_embeddings else "head", "embed")
    new_cache = {f"scan/{k}": v for k, v in upd.items()}
    new_cache["pos"] = cache["pos"] + 1
    return new_cache, logits


def cache_spec(cfg: ModelConfig, batch: int, smax: int) -> Dict[str, Tuple]:
    s = cfg.ssm
    d_in, h, conv_dim = _dims(cfg)
    g, r = s.n_groups, (d_in // s.head_dim) // s.n_groups
    ll = cfg.num_layers
    return {
        "scan/conv": ((ll, batch, s.d_conv - 1, conv_dim), jnp.bfloat16,
                      ("layers", "batch", "conv", "ssm_inner")),
        "scan/h": ((ll, batch, g, r, s.head_dim, s.d_state), F32,
                   ("layers", "batch", "groups", "ssm_heads", "head_dim",
                    "state")),
        "pos": ((), jnp.int32, ()),
    }
