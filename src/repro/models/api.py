"""Uniform Model API: one facade over every architecture family.

Provides:
  init_params(cfg)        — concrete (reduced/smoke) or abstract (dry-run)
  loss_fn / prefill / decode_step dispatchers
  input_specs(cfg, shape) — ShapeDtypeStruct stand-ins for every model input
  cache_specs(cfg, B, S)  — decode-cache ShapeDtypeStructs + logical axes
  analytic_param_count    — N for the 6·N·D roofline term
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.param import Registrar

_FAMILIES: Dict[str, Any] = {}


def _family(cfg: ModelConfig):
    if not _FAMILIES:
        from repro.models import (transformer, mamba2, recurrentgemma,
                                  encdec, vlm)
        _FAMILIES.update({
            "transformer": transformer,
            "ssm": mamba2,
            "hybrid": recurrentgemma,
            "encdec": encdec,
            "vlm": vlm,
        })
    return _FAMILIES[cfg.family]


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0, abstract: bool = False
                ) -> Tuple[Dict[str, Any], Dict[str, Tuple[str, ...]]]:
    """Returns (params, logical_axes). abstract => ShapeDtypeStructs only."""
    reg = Registrar(abstract=abstract, seed=seed,
                    dtype=jnp.dtype(cfg.param_dtype))
    _family(cfg).init_params(reg, cfg)
    return reg.params, reg.axes


_QUANT_SKIP = ("norm", "scale", "router", "gate_attn", "gate_mlp", "lam",
               "A_log", "dt_bias", "/b")


def quantize_for_serving(cfg: ModelConfig, params: Dict[str, Any],
                         axes: Dict[str, Tuple[str, ...]]
                         ) -> Tuple[Dict[str, Any], Dict[str, Tuple[str, ...]]]:
    """FENIX Model Engine INT8 applied to LM weights (serve path only).

    Matmul weights become int8 + a per-tensor scale (per-layer for scanned
    stacks).  Works on abstract (ShapeDtypeStruct) and concrete params.
    Halves the weight-read bytes of memory-bound decode — §Perf lever.
    """
    new_p, new_ax = {}, {}
    for k, v in params.items():
        new_p[k], new_ax[k] = v, axes[k]
        if v.ndim < 2 or any(s in k for s in _QUANT_SKIP):
            continue
        if not (k.endswith("/w") or k.endswith("/table")
                or "/experts/" in k):
            continue
        stacked = axes[k][0] == "layers"
        sshape = (v.shape[0],) if stacked else ()
        sax = ("layers",) if stacked else ()
        if isinstance(v, jax.ShapeDtypeStruct):
            new_p[k] = jax.ShapeDtypeStruct(v.shape, jnp.int8)
            new_p[f"{k}_scale"] = jax.ShapeDtypeStruct(sshape, jnp.float32)
        else:
            w = jnp.asarray(v, jnp.float32)
            red = tuple(range(1, w.ndim)) if stacked else None
            amax = jnp.max(jnp.abs(w), axis=red) if stacked \
                else jnp.max(jnp.abs(w))
            scale = jnp.maximum(amax, 1e-8) / 127.0
            sc = scale.reshape(sshape + (1,) * (w.ndim - len(sshape)))
            new_p[k] = jnp.clip(jnp.round(w / sc), -127, 127).astype(jnp.int8)
            new_p[f"{k}_scale"] = scale.astype(jnp.float32)
        new_ax[k] = axes[k]
        new_ax[f"{k}_scale"] = sax
    return new_p, new_ax


def loss_fn(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, Dict]:
    return _family(cfg).loss_fn(params, cfg, batch)


def prefill(params, cfg: ModelConfig, batch):
    fam = _family(cfg)
    if cfg.family in ("encdec", "vlm"):
        return fam.prefill(params, cfg, batch)
    return fam.prefill(params, cfg, batch["tokens"])


def decode_step(params, cfg: ModelConfig, cache, tokens):
    return _family(cfg).decode_step(params, cfg, cache, tokens)


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, smax: int,
                src_len: Optional[int] = None
                ) -> Dict[str, Tuple[Tuple[int, ...], Any, Tuple[str, ...]]]:
    fam = _family(cfg)
    if cfg.family == "encdec":
        return fam.cache_spec(cfg, batch, smax,
                              src_len=src_len if src_len else smax)
    return fam.cache_spec(cfg, batch, smax)


def grow_cache(cfg: ModelConfig, cache: Dict[str, Any], batch: int,
               old_smax: int, new_smax: int,
               src_len: Optional[int] = None) -> Dict[str, Any]:
    """Zero-pad the kv_seq axes of a prefill cache so decode can append.

    Identifies the sequence axis per entry by diffing cache_specs at the two
    lengths (cross-attention / ring / SSM entries are untouched).
    """
    old = cache_specs(cfg, batch, old_smax, src_len=src_len)
    new = cache_specs(cfg, batch, new_smax, src_len=src_len)
    out = dict(cache)
    for k, (oshp, _dt, _ax) in old.items():
        nshp = new[k][0]
        if oshp == nshp or k not in cache:
            continue
        widths = [(0, n - o) for o, n in zip(oshp, nshp)]
        arr = cache[k]
        # the cache entry may lack the stacking dim match (prefill emits
        # exactly spec-shaped arrays), pad on the differing axes
        widths = [(0, n - o) for o, n in zip(arr.shape, nshp[-arr.ndim:])] \
            if arr.ndim != len(oshp) else widths
        out[k] = jnp.pad(arr, widths)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step function inputs.

    train  -> {tokens, labels [, src_embeds | image_embeds]}
    prefill-> {tokens [, src_embeds | image_embeds]}
    decode -> {tokens [B], cache: {...}}
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    act = jnp.dtype(cfg.activation_dtype)
    tok = jax.ShapeDtypeStruct((b, s), i32)
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        out["tokens"] = tok
        out["labels"] = tok
        if cfg.family == "encdec":
            out["src_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), act)
        if cfg.family == "vlm":
            out["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_image_tokens, cfg.d_model), act)
        return out
    if shape.kind == "prefill":
        out["tokens"] = tok
        if cfg.family == "encdec":
            out["src_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), act)
        if cfg.family == "vlm":
            out["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_image_tokens, cfg.d_model), act)
        return out
    # decode: single token + KV cache of seq_len
    out["tokens"] = jax.ShapeDtypeStruct((b,), i32)
    cache = {}
    for name, (shp, dt, _ax) in cache_specs(cfg, b, s).items():
        cache[name] = jax.ShapeDtypeStruct(shp, dt)
    out["cache"] = cache
    return out


def cache_pspec_axes(cfg: ModelConfig, batch: int, smax: int
                     ) -> Dict[str, Tuple[str, ...]]:
    return {k: ax for k, (shp, dt, ax) in
            cache_specs(cfg, batch, smax).items()}


# ---------------------------------------------------------------------------
# Analytic parameter counts (for MODEL_FLOPS = 6*N*D)
# ---------------------------------------------------------------------------


def analytic_param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Matmul-participating parameters per token.

    Excludes the embedding *gather* (not a matmul); includes the LM head
    (tied or not — the logits matmul runs either way).  For MoE with
    active_only=True, routed experts count top_k of num_experts.
    """
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    def attn_gqa() -> int:
        return d * h * dh + 2 * d * hkv * dh + h * dh * d

    def attn_mla() -> int:
        dn, dr, r = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.kv_lora_rank
        n = 0
        if cfg.q_lora_rank:
            n += d * cfg.q_lora_rank + cfg.q_lora_rank * h * (dn + dr)
        else:
            n += d * h * (dn + dr)
        n += d * r + d * dr + r * h * dn + r * h * cfg.v_head_dim
        n += h * cfg.v_head_dim * d
        return n

    def mlp_dense(ff) -> int:
        return 3 * d * ff

    total = 0
    if cfg.family == "transformer":
        attn = attn_mla() if cfg.attention == "mla" else attn_gqa()
        m = cfg.moe
        if m.num_experts:
            n_first = m.first_dense_layers
            total += n_first * (attn + mlp_dense(m.first_dense_d_ff))
            n_moe = cfg.num_layers - n_first
            e_cnt = m.top_k if active_only else m.num_experts
            per = (attn + d * m.num_experts            # router
                   + e_cnt * 3 * d * m.expert_d_ff
                   + (3 * d * m.shared_d_ff if m.num_shared_experts else 0))
            total += n_moe * per
        else:
            total += cfg.num_layers * (attn + mlp_dense(f))
    elif cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * d
        gn = s.n_groups * s.d_state
        nh = d_in // s.head_dim
        per = 2 * d * d_in + 2 * d * gn + d * nh + d_in * d
        total += cfg.num_layers * per
    elif cfg.family == "hybrid":
        w = cfg.hybrid.lru_width or d
        pat = cfg.hybrid.pattern
        n_rec = sum(1 for i in range(cfg.num_layers)
                    if pat[i % len(pat)] == "recurrent") \
            if cfg.num_layers % len(pat) == 0 else None
        # generic: count by walking the pattern
        n_rec = 0
        n_att = 0
        for i in range(cfg.num_layers):
            if pat[i % len(pat)] == "recurrent":
                n_rec += 1
            else:
                n_att += 1
        rec = 2 * d * w + 2 * (w * w) // 16 + w * d
        total += n_rec * rec + n_att * attn_gqa()
        total += cfg.num_layers * mlp_dense(f)
    elif cfg.family == "encdec":
        enc = cfg.num_encoder_layers * (attn_gqa() + mlp_dense(f))
        dec = cfg.num_decoder_layers * (2 * attn_gqa() + mlp_dense(f))
        total += enc + dec
    elif cfg.family == "vlm":
        per, n_super = cfg.cross_attn_every, cfg.num_layers // cfg.cross_attn_every
        total += n_super * ((per - 1) * (attn_gqa() + mlp_dense(f))
                            + attn_gqa() + mlp_dense(f))
    else:
        raise ValueError(cfg.family)
    total += d * v  # logits head matmul
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6*N*D convention. For decode shapes D = global_batch (1 token each);
    attention-over-cache FLOPs are additionally included (2*bytes-free term:
    2 * B * S * kv_width) since they dominate long-context decode."""
    n = analytic_param_count(cfg, active_only=True)
    if shape.kind == "train":
        d_tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * d_tokens
    if shape.kind == "prefill":
        d_tokens = shape.global_batch * shape.seq_len
        flops = 2.0 * n * d_tokens
        flops += _attn_flops(cfg, shape.global_batch, shape.seq_len)
        return flops
    # decode: one token per sequence
    flops = 2.0 * n * shape.global_batch
    flops += _decode_attn_flops(cfg, shape.global_batch, shape.seq_len)
    return flops


def _attn_flops(cfg: ModelConfig, b: int, s: int) -> float:
    """Causal self-attention matmul FLOPs (scores + combine), per model."""
    if cfg.family == "ssm":
        return 0.0
    h, dh = cfg.num_heads, cfg.head_dim
    if cfg.attention == "mla":
        dh = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    full = 2.0 * 2.0 * b * h * dh * s * s / 2.0      # causal half
    if cfg.family == "hybrid":
        win = cfg.hybrid.attention_window
        pat = cfg.hybrid.pattern
        n_att = sum(1 for i in range(cfg.num_layers)
                    if pat[i % len(pat)] != "recurrent")
        per = 2.0 * 2.0 * b * h * dh * s * min(win, s)
        return n_att * per
    n_layers = cfg.num_layers if cfg.family != "encdec" \
        else cfg.num_encoder_layers + 2 * cfg.num_decoder_layers
    return n_layers * full


def _decode_attn_flops(cfg: ModelConfig, b: int, s: int) -> float:
    if cfg.family == "ssm":
        s_cfg = cfg.ssm
        d_in = s_cfg.expand * cfg.d_model
        nh = d_in // s_cfg.head_dim
        per = 2.0 * 2.0 * b * nh * s_cfg.head_dim * s_cfg.d_state
        return cfg.num_layers * per
    h, dh = cfg.num_heads, cfg.head_dim
    if cfg.attention == "mla":
        # absorbed decode: q_abs@ckv + probs@ckv over rank R
        r = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        return cfg.num_layers * 2.0 * 2.0 * b * cfg.num_heads * r * s
    eff_s = s
    if cfg.family == "hybrid":
        win = cfg.hybrid.attention_window
        pat = cfg.hybrid.pattern
        n_att = sum(1 for i in range(cfg.num_layers)
                    if pat[i % len(pat)] != "recurrent")
        n_rec = cfg.num_layers - n_att
        w = cfg.hybrid.lru_width or cfg.d_model
        return (n_att * 2.0 * 2.0 * b * h * dh * min(win, s)
                + n_rec * 2.0 * b * w)
    n_layers = cfg.num_layers if cfg.family != "encdec" \
        else cfg.num_decoder_layers
    per = 2.0 * 2.0 * b * h * dh * eff_s
    if cfg.family == "encdec":
        per *= 2  # self + cross
    if cfg.family == "vlm":
        n_cross = cfg.num_layers // cfg.cross_attn_every
        per_cross = 2.0 * 2.0 * b * h * dh * cfg.num_image_tokens
        return (cfg.num_layers - n_cross) * per + n_cross * per_cross
    return n_layers * per
