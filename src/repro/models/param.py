"""Parameter registration + logical-axis sharding.

Single source of truth for parameter shapes, dtypes, init distributions and
*logical* sharding axes.  A ``Registrar`` is threaded through every ``init``
function; in ``abstract`` mode it yields ``jax.ShapeDtypeStruct`` (used by the
multi-pod dry-run — full-size configs are never materialized), in concrete
mode it yields numpy-initialized ``jnp`` arrays (reduced smoke configs, FENIX
traffic models).

Logical axes are mapped to mesh axes through ``Rules`` (MaxText-style).  The
mapping automatically drops a mesh axis whose size does not divide the array
dimension (e.g. qwen2.5's 40 heads on a 16-way model axis) — the fallback is
recorded so EXPERIMENTS.md can report it.
"""

from __future__ import annotations

import contextlib
import hashlib
import threading
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Tuple[str, ...]
MeshAxes = Union[str, Tuple[str, ...], None]


# ---------------------------------------------------------------------------
# Logical -> mesh rules
# ---------------------------------------------------------------------------

# Baseline rule set (the §Perf hillclimb mutates copies of this).
# "embed" -> (pod, data) is the FSDP axis: weights 2-D sharded (data x model).
# TP-only (embed -> None) was rejected by memory_analysis: deepseek-v2 train
# needs 153 GB/device with data-replicated params+Adam (see EXPERIMENTS.md).
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "seq": None,
    # Megatron-style sequence parallelism for the saved residual stream at
    # block boundaries (remat/scan carries shrink 16x; attention re-gathers):
    "act_seq": "model",
    "kv_seq": "model",        # decode-time sequence sharding of the KV cache
    "vocab": "model",
    "embed": ("pod", "data"),
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qk_dim": None,
    "v_dim": None,
    "ffn": "model",
    "experts": "model",
    "expert_cap": None,
    "moe_flat": "model",            # flat [E*cap] rows, expert-aligned
    "moe_tokens": ("pod", "data"),  # flat [T*k] token rows
    "q_lora": None,
    "kv_lora": None,
    "ssm_inner": "model",
    "ssm_heads": "model",
    "state": None,
    "groups": None,
    "lru": "model",
    "conv": None,
    "layers": None,
    "blocks": None,
    "img_seq": None,
    "classes": None,
    "feat": None,
    "stack": None,
}


class _Ctx(threading.local):
    def __init__(self) -> None:
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, MeshAxes] = dict(DEFAULT_RULES)
        self.fallbacks: list = []


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_ctx(mesh: Optional[Mesh], rules: Optional[Dict[str, MeshAxes]] = None):
    """Activate a mesh + rule set; layer code then annotates activations."""
    prev = (_CTX.mesh, _CTX.rules, _CTX.fallbacks)
    _CTX.mesh = mesh
    _CTX.rules = dict(DEFAULT_RULES)
    if rules:
        _CTX.rules.update(rules)
    _CTX.fallbacks = []
    try:
        yield _CTX
    finally:
        _CTX.mesh, _CTX.rules, _CTX.fallbacks = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _mesh_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _filter_mesh_axes(mesh: Mesh, axes: MeshAxes) -> MeshAxes:
    """Drop mesh axes not present in this mesh (e.g. 'pod' on single-pod)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.axis_names else None
    kept = tuple(a for a in axes if a in mesh.axis_names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def spec_for(shape: Sequence[int], axes: Axes,
             mesh: Optional[Mesh] = None,
             rules: Optional[Dict[str, MeshAxes]] = None) -> P:
    """PartitionSpec for ``shape`` given logical ``axes`` under active rules.

    Divisibility-guarded: a mesh axis that does not divide the dimension is
    dropped (recorded in ``_CTX.fallbacks``).
    """
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if mesh is None:
        return P()
    assert len(shape) == len(axes), (shape, axes)
    out = []
    used: set = set()
    for dim, ax in zip(shape, axes):
        m = _filter_mesh_axes(mesh, rules.get(ax))
        if m is None:
            out.append(None)
            continue
        maxes = (m,) if isinstance(m, str) else m
        # a mesh axis may appear only once in a PartitionSpec
        maxes = tuple(a for a in maxes if a not in used)
        if not maxes:
            out.append(None)
            continue
        size = _mesh_size(mesh, maxes)
        if dim % size != 0:
            _CTX.fallbacks.append((tuple(shape), ax, m, dim, size))
            out.append(None)
            continue
        used.update(maxes)
        out.append(maxes if len(maxes) > 1 else maxes[0])
    return P(*out)


def shard(x: jax.Array, *axes: str) -> jax.Array:
    """with_sharding_constraint on an activation, guarded by context."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = spec_for(x.shape, tuple(axes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def replicate(x: jax.Array) -> jax.Array:
    """Force full replication (one explicit all-gather instead of leaving
    GSPMD to thread computed-index gathers through permute chains)."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*([None] * x.ndim))))


def sharding_fallbacks() -> list:
    return list(_CTX.fallbacks)


# ---------------------------------------------------------------------------
# Registrar
# ---------------------------------------------------------------------------


def _seed_for(path: str, seed: int) -> np.random.Generator:
    h = hashlib.sha256(f"{seed}:{path}".encode()).digest()
    return np.random.default_rng(int.from_bytes(h[:8], "little"))


class Registrar:
    """Records parameter metadata; materializes concretely or abstractly."""

    def __init__(self, abstract: bool = False, seed: int = 0,
                 dtype: Any = jnp.bfloat16):
        self.abstract = abstract
        self.seed = seed
        self.default_dtype = dtype
        self.params: Dict[str, Any] = {}
        self.axes: Dict[str, Axes] = {}

    def param(self, path: str, shape: Sequence[int], axes: Iterable[str],
              init: str = "normal", scale: Optional[float] = None,
              dtype: Any = None) -> Any:
        axes = tuple(axes)
        shape = tuple(int(s) for s in shape)
        assert len(axes) == len(shape), (path, shape, axes)
        assert path not in self.params, f"duplicate param {path}"
        dtype = dtype or self.default_dtype
        self.axes[path] = axes
        if self.abstract:
            val = jax.ShapeDtypeStruct(shape, dtype)
        else:
            rng = _seed_for(path, self.seed)
            if init == "normal":
                if scale is None:
                    # fan-in scaling over the last-but-one dims heuristically:
                    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
                    scale = fan_in ** -0.5
                arr = rng.normal(0.0, scale, size=shape)
            elif init == "zeros":
                arr = np.zeros(shape)
            elif init == "ones":
                arr = np.ones(shape)
            elif init == "uniform":
                s = scale if scale is not None else 1.0
                arr = rng.uniform(-s, s, size=shape)
            else:
                raise ValueError(init)
            val = jnp.asarray(arr, dtype=dtype)
        self.params[path] = val
        return val

    # -- helpers -----------------------------------------------------------
    def pspecs(self, mesh: Mesh, rules: Optional[Dict[str, MeshAxes]] = None
               ) -> Dict[str, P]:
        return {
            k: spec_for(v.shape, self.axes[k], mesh=mesh, rules=rules)
            for k, v in self.params.items()
        }


def subtree(params: Dict[str, Any], prefix: str) -> Dict[str, Any]:
    """Extract a flat sub-dict (keys relative to prefix)."""
    out = {}
    for k, v in params.items():
        if k.startswith(prefix):
            out[k[len(prefix):]] = v
    return out


def tree_pspecs(params: Dict[str, Any], axes: Dict[str, Axes], mesh: Mesh,
                rules: Optional[Dict[str, MeshAxes]] = None) -> Dict[str, P]:
    return {k: spec_for(v.shape, axes[k], mesh=mesh, rules=rules)
            for k, v in params.items()}


def maybe_scan(body, carry, stacked, use_scan: bool):
    """lax.scan or an unrolled python loop (the no-while cost-analysis path).

    ``stacked``: pytree with equal leading dims; ``body(carry, slice)`` ->
    (carry, ys_slice) where ys_slice is None or a pytree.
    """
    import jax.numpy as jnp

    if use_scan:
        return jax.lax.scan(body, carry, stacked)
    leaves = jax.tree.leaves(stacked)
    n = leaves[0].shape[0]
    ys_list = []
    for i in range(n):
        sl = jax.tree.map(lambda a: a[i], stacked)
        carry, ys = body(carry, sl)
        ys_list.append(ys)
    if ys_list and ys_list[0] is not None:
        ys = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *ys_list)
    else:
        ys = None
    return carry, ys
