"""Gemma-7B — dense decoder LM with GeGLU and head_dim=256.

[arXiv:2403.08295; hf]  28L d_model=3072 16H (MHA kv=16) d_ff=24576 (GeGLU)
vocab=256000, head_dim=256, tied embeddings.
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b",
        family="transformer",
        num_layers=28,
        d_model=3072,
        num_heads=16,
        num_kv_heads=16,
        head_dim=256,
        d_ff=24_576,
        vocab_size=256_000,
        attention="gqa",
        mlp_act="gelu",
        rope_theta=10_000.0,
        tie_embeddings=True,
        source="arXiv:2403.08295; hf",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b-reduced",
        family="transformer",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        attention="gqa",
        mlp_act="gelu",
        tie_embeddings=True,
        attn_chunk_q=32,
        attn_chunk_kv=32,
        source="reduced smoke variant",
    )


register("gemma-7b", full, reduced)
