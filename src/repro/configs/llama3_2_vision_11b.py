"""Llama-3.2-11B-Vision backbone — decoder LM with interleaved cross-attention.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]  40L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=128256; cross-attention image layers every 5th
layer (8 total).  The vision frontend is a STUB per the task spec:
``input_specs()`` provides precomputed patch embeddings (B, S_img, d_model).
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14_336,
        vocab_size=128_256,
        attention="gqa",
        rope_theta=500_000.0,
        cross_attn_every=5,
        num_image_tokens=4096,
        source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b-reduced",
        family="vlm",
        num_layers=5,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        attention="gqa",
        cross_attn_every=5,
        num_image_tokens=16,
        attn_chunk_q=32,
        attn_chunk_kv=32,
        source="reduced smoke variant",
    )


register("llama-3.2-vision-11b", full, reduced)
