"""Qwen3-4B — dense decoder LM with per-head QK-RMSNorm and GQA.

[hf:Qwen/Qwen3-4B; hf]  36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936, head_dim=128, qk_norm, tied embeddings.
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        family="transformer",
        num_layers=36,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab_size=151_936,
        attention="gqa",
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        source="hf:Qwen/Qwen3-4B; hf",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b-reduced",
        family="transformer",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        attention="gqa",
        qk_norm=True,
        tie_embeddings=True,
        attn_chunk_q=32,
        attn_chunk_kv=32,
        source="reduced smoke variant",
    )


register("qwen3-4b", full, reduced)
