"""Config system for the repro framework.

One ``ModelConfig`` dataclass covers every assigned architecture family:
dense / MoE decoder LMs (with GQA, MLA, qk-norm, GLU variants), SSM (mamba2),
hybrid (recurrentgemma), encoder-decoder (seamless-m4t) and VLM
(llama-3.2-vision).  Architectures register themselves into ``REGISTRY`` and
are selectable with ``--arch <id>`` everywhere (dryrun, train, serve, tests).

Every architecture provides a ``reduced()`` variant used by CPU smoke tests;
the full config is only ever touched abstractly (ShapeDtypeStruct) by the
multi-pod dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set, identical for all 10 LM-family archs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0          # routed experts (0 => dense MLP)
    top_k: int = 0
    expert_d_ff: int = 0          # per-expert intermediate size
    num_shared_experts: int = 0   # always-on shared experts
    shared_d_ff: int = 0          # total intermediate of the shared expert(s)
    shared_gated: bool = False    # qwen2-moe gates the shared expert output
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    first_dense_layers: int = 0   # deepseek-v2: layer 0 is a dense MLP
    first_dense_d_ff: int = 0
    aux_loss_weight: float = 0.001
    dispatch_chunks: int = 1      # split token dispatch to bound the
    #                               replicated gather working set (§Perf)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256
    # derived: d_inner = expand * d_model; n_heads = d_inner // head_dim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """recurrentgemma: repeating block pattern of recurrent + local-attn layers."""

    pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")
    lru_width: int = 0            # 0 => d_model
    conv_width: int = 4
    attention_window: int = 2048
    block_rank: int = 0           # low-rank input/gate projections (0 => full)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # "transformer" | "ssm" | "hybrid" | "encdec" | "vlm"
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    # --- attention flavour ---
    attention: str = "gqa"        # "gqa" | "mla" | "none"
    qk_norm: bool = False         # qwen3
    qkv_bias: bool = False        # qwen2.5
    mlp_act: str = "silu"         # "silu" (SwiGLU) | "gelu" (GeGLU)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # --- MLA (deepseek-v2) ---
    q_lora_rank: int = 0          # 0 => full-rank q projection
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # --- optional sub-configs ---
    moe: MoEConfig = dataclasses.field(default_factory=MoEConfig)
    ssm: SSMConfig = dataclasses.field(default_factory=SSMConfig)
    hybrid: HybridConfig = dataclasses.field(default_factory=HybridConfig)
    # --- encdec ---
    num_encoder_layers: int = 0
    num_decoder_layers: int = 0
    # --- vlm ---
    cross_attn_every: int = 0     # insert a cross-attn layer every N layers
    num_image_tokens: int = 0     # stub vision frontend sequence length
    # --- execution knobs (perf levers; see EXPERIMENTS §Perf) ---
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    remat_policy: str = "nothing"     # "nothing" | "dots" | "none" (no remat)
    attention_impl: str = "bands"     # "naive" | "chunked" | "bands"
    attn_chunk_q: int = 1024
    attn_chunk_kv: int = 1024
    scan_layers: bool = True
    quant: str = "none"               # "none" | "int8" (weights, serve path)
    kv_cache_dtype: str = "bfloat16"  # "bfloat16" | "int8" (decode cache)
    # --- notes ---
    source: str = ""
    sub_quadratic: bool = False   # eligible for long_500k

    # ---- derived helpers -------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks). Used for 6ND."""
        from repro.models.api import analytic_param_count

        return analytic_param_count(self)

    def active_param_count(self) -> int:
        from repro.models.api import analytic_param_count

        return analytic_param_count(self, active_only=True)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
REDUCED: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig],
             reduced: Callable[[], ModelConfig]) -> None:
    REGISTRY[name] = full
    REDUCED[name] = reduced


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    _ensure_imported()
    table = REDUCED if reduced else REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]()


def list_archs() -> Tuple[str, ...]:
    _ensure_imported()
    return tuple(sorted(REGISTRY))


_IMPORTED = False


def _ensure_imported() -> None:
    global _IMPORTED
    if _IMPORTED:
        return
    # import all config modules for their registration side effects
    from repro.configs import (  # noqa: F401
        deepseek_v2_236b,
        qwen2_moe_a2_7b,
        llama3_2_1b,
        qwen2_5_14b,
        qwen3_4b,
        gemma_7b,
        mamba2_370m,
        recurrentgemma_9b,
        seamless_m4t_medium,
        llama3_2_vision_11b,
    )

    _IMPORTED = True


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs; reason recorded in DESIGN.md §4."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: full-attention arch (no sub-quadratic path)"
    return True, ""
