"""RecurrentGemma-9B (Griffin) — RG-LRU + local-attention hybrid, 2:1 pattern.

[arXiv:2402.19427; unverified]  38L d_model=4096 16H (MQA kv=1) d_ff=12288
(GeGLU) vocab=256000, attention window 2048, lru_width=4096, conv1d width 4.
Pattern: (recurrent, recurrent, attention) repeating; 38 = 12*(r,r,a) + (r,r).
Sub-quadratic: eligible for long_500k (O(window) attention + O(1) RG-LRU state).
"""

from repro.configs.base import HybridConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        d_ff=12_288,
        vocab_size=256_000,
        attention="gqa",
        mlp_act="gelu",
        rope_theta=10_000.0,
        tie_embeddings=True,
        hybrid=HybridConfig(
            pattern=("recurrent", "recurrent", "attention"),
            lru_width=4096,
            conv_width=4,
            attention_window=2048,
        ),
        sub_quadratic=True,
        source="arXiv:2402.19427; unverified",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-reduced",
        family="hybrid",
        num_layers=5,  # (r, r, a) + (r, r)
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        attention="gqa",
        mlp_act="gelu",
        tie_embeddings=True,
        hybrid=HybridConfig(
            pattern=("recurrent", "recurrent", "attention"),
            lru_width=64,
            conv_width=4,
            attention_window=32,
        ),
        attn_chunk_q=32,
        attn_chunk_kv=32,
        sub_quadratic=True,
        source="reduced smoke variant",
    )


register("recurrentgemma-9b", full, reduced)
