"""SeamlessM4T-medium backbone — encoder-decoder transformer.

[arXiv:2308.11596; hf]  12L encoder + 12L decoder, d_model=1024 16H (MHA kv=16)
d_ff=4096 vocab=256206.  The audio/speech frontend is a STUB per the task spec:
``input_specs()`` provides precomputed frame embeddings (B, S, d_model).
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        family="encdec",
        num_layers=24,
        num_encoder_layers=12,
        num_decoder_layers=12,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=256_206,
        attention="gqa",
        mlp_act="silu",
        source="arXiv:2308.11596; hf",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium-reduced",
        family="encdec",
        num_layers=4,
        num_encoder_layers=2,
        num_decoder_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        attention="gqa",
        attn_chunk_q=32,
        attn_chunk_kv=32,
        source="reduced smoke variant",
    )


register("seamless-m4t-medium", full, reduced)
