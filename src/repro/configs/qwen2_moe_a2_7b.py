"""Qwen1.5/2-MoE-A2.7B — MoE decoder LM with gated shared expert.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]  24L d_model=2048 16H (MHA kv=16)
d_ff(expert)=1408 vocab=151936, 60 routed experts top-4 + 4 shared
(shared intermediate 4*1408=5632, sigmoid-gated).
"""

from repro.configs.base import ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="transformer",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=151_936,
        attention="gqa",
        qkv_bias=True,
        rope_theta=1_000_000.0,
        moe=MoEConfig(
            num_experts=60,
            top_k=4,
            expert_d_ff=1408,
            num_shared_experts=4,
            shared_d_ff=5632,
            shared_gated=True,
        ),
        source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-reduced",
        family="transformer",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=96,
        vocab_size=512,
        attention="gqa",
        qkv_bias=True,
        moe=MoEConfig(
            num_experts=8,
            top_k=2,
            expert_d_ff=96,
            num_shared_experts=2,
            shared_d_ff=192,
            shared_gated=True,
        ),
        attn_chunk_q=32,
        attn_chunk_kv=32,
        source="reduced smoke variant",
    )


register("qwen2-moe-a2.7b", full, reduced)
