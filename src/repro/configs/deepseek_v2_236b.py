"""DeepSeek-V2 236B — MoE decoder LM with Multi-head Latent Attention (MLA).

[arXiv:2405.04434; hf]  60L d_model=5120 128H d_ff(expert)=1536 vocab=102400,
MoE 160 routed experts top-6 + 2 shared, MLA kv_lora_rank=512.
"""

from repro.configs.base import ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="transformer",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,          # MLA: per-head K/V decompressed from the latent
        head_dim=128,              # qk_nope/v head dim
        d_ff=1536,                 # routed-expert intermediate (assignment value)
        vocab_size=102_400,
        attention="mla",
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        rope_theta=10_000.0,
        moe=MoEConfig(
            num_experts=160,
            top_k=6,
            expert_d_ff=1536,
            num_shared_experts=2,
            shared_d_ff=2 * 1536,
            first_dense_layers=1,
            first_dense_d_ff=12_288,
        ),
        source="arXiv:2405.04434; hf",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-reduced",
        family="transformer",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=96,
        vocab_size=512,
        attention="mla",
        q_lora_rank=32,
        kv_lora_rank=24,
        qk_nope_head_dim=16,
        qk_rope_head_dim=8,
        v_head_dim=16,
        moe=MoEConfig(
            num_experts=8,
            top_k=2,
            expert_d_ff=96,
            num_shared_experts=2,
            shared_d_ff=192,
            first_dense_layers=1,
            first_dense_d_ff=256,
        ),
        attn_chunk_q=32,
        attn_chunk_kv=32,
        source="reduced smoke variant",
    )


register("deepseek-v2-236b", full, reduced)
