"""Llama-3.2-1B — small dense decoder LM with GQA.

[hf:meta-llama/Llama-3.2-1B; unverified]  16L d_model=2048 32H (GQA kv=8)
d_ff=8192 vocab=128256, head_dim=64, tied embeddings.
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b",
        family="transformer",
        num_layers=16,
        d_model=2048,
        num_heads=32,
        num_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=128_256,
        attention="gqa",
        rope_theta=500_000.0,
        tie_embeddings=True,
        source="hf:meta-llama/Llama-3.2-1B; unverified",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b-reduced",
        family="transformer",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        attention="gqa",
        tie_embeddings=True,
        attn_chunk_q=32,
        attn_chunk_kv=32,
        source="reduced smoke variant",
    )


register("llama3.2-1b", full, reduced)
