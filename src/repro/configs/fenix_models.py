"""Configs for the paper's own traffic-analysis models (§7.1 schemes a/b/d/e).

FENIX-CNN: 3 conv layers (64, 128, 256 filters) + 2 FC layers (512, 256).
FENIX-RNN: embeddings + single custom RNN cell (128 units) + dense output.

Features per the paper §6: sequences of packet lengths and inter-packet
arrival times (protocol-agnostic), 8 buffered + 1 current = 9-step windows.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class TrafficModelConfig:
    name: str
    kind: str                       # "cnn" | "rnn"
    num_classes: int
    seq_len: int = 9                # ring depth 8 + current feature (paper §4.3)
    # feature vocabulary (embedding path; packet length / IPD are bucketized)
    len_buckets: int = 64
    ipd_buckets: int = 64
    embed_dim: int = 16
    # CNN
    conv_filters: Tuple[int, ...] = (64, 128, 256)
    conv_kernel: int = 3
    fc_dims: Tuple[int, ...] = (512, 256)
    # RNN
    rnn_units: int = 128
    # quantization (Model Engine is INT8; §6 "Model Training and Quantization")
    quant_bits: int = 8


def fenix_cnn(num_classes: int = 7) -> TrafficModelConfig:
    return TrafficModelConfig(name="fenix-cnn", kind="cnn", num_classes=num_classes)


def fenix_rnn(num_classes: int = 7) -> TrafficModelConfig:
    return TrafficModelConfig(name="fenix-rnn", kind="rnn", num_classes=num_classes)


def fenix_cnn_tiny(num_classes: int = 7) -> TrafficModelConfig:
    """CI-sized CNN: same layer structure as the paper model, shrunk so a
    trained + quantized instance serves inside the tier-1 test budget
    (the serving-loop conformance suite trains one per session)."""
    return TrafficModelConfig(name="fenix-cnn-tiny", kind="cnn",
                              num_classes=num_classes, embed_dim=4,
                              conv_filters=(8,), fc_dims=(16,))


def fenix_rnn_tiny(num_classes: int = 7) -> TrafficModelConfig:
    """CI-sized RNN counterpart of :func:`fenix_cnn_tiny`."""
    return TrafficModelConfig(name="fenix-rnn-tiny", kind="rnn",
                              num_classes=num_classes, embed_dim=4,
                              rnn_units=16)


# serving-model registry: the ``FenixConfig(model=...)`` names that map to
# a quantized EngineModel ("bylen" is handled by the serving factory)
MODEL_CONFIGS = {
    "int8_cnn": fenix_cnn,
    "int8_rnn": fenix_rnn,
    "int8_cnn_tiny": fenix_cnn_tiny,
    "int8_rnn_tiny": fenix_rnn_tiny,
}


def model_config(name: str, num_classes: int = 7) -> TrafficModelConfig:
    """Resolve a ``FenixConfig.model`` name to its TrafficModelConfig."""
    if name not in MODEL_CONFIGS:
        raise ValueError(f"unknown model {name!r}; expected one of "
                         f"{('bylen',) + tuple(sorted(MODEL_CONFIGS))}")
    return MODEL_CONFIGS[name](num_classes)
