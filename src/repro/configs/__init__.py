from repro.configs.base import (  # noqa: F401
    MoEConfig,
    ModelConfig,
    REDUCED,
    REGISTRY,
    SHAPES,
    ShapeConfig,
    SSMConfig,
    HybridConfig,
    get_config,
    list_archs,
    register,
    shape_applicable,
)
from repro.configs.fenix_models import (  # noqa: F401
    TrafficModelConfig,
    fenix_cnn,
    fenix_rnn,
)
