"""Mamba2-370M — attention-free SSM LM using SSD (state-space duality).

[arXiv:2405.21060; unverified]  48L d_model=1024, ssm_state=128, expand=2
(d_inner=2048, head_dim=64 -> 32 ssm heads), d_conv=4, vocab=50280.
Sub-quadratic: eligible for long_500k.
"""

from repro.configs.base import ModelConfig, SSMConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        num_layers=48,
        d_model=1024,
        vocab_size=50_280,
        attention="none",
        tie_embeddings=True,
        ssm=SSMConfig(
            d_state=128,
            d_conv=4,
            expand=2,
            head_dim=64,
            n_groups=1,
            chunk_size=256,
        ),
        sub_quadratic=True,
        source="arXiv:2405.21060; unverified",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m-reduced",
        family="ssm",
        num_layers=2,
        d_model=64,
        vocab_size=512,
        attention="none",
        tie_embeddings=True,
        ssm=SSMConfig(
            d_state=16,
            d_conv=4,
            expand=2,
            head_dim=16,
            n_groups=1,
            chunk_size=32,
        ),
        sub_quadratic=True,
        source="reduced smoke variant",
    )


register("mamba2-370m", full, reduced)
