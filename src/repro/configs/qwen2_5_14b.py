"""Qwen2.5-14B — dense decoder LM with GQA and QKV bias.

[hf:Qwen/Qwen2.5-14B; hf]  48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064, head_dim=128, QKV bias.
"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b",
        family="transformer",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=13_824,
        vocab_size=152_064,
        attention="gqa",
        qkv_bias=True,
        rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen2.5-14B; hf",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b-reduced",
        family="transformer",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        attention="gqa",
        qkv_bias=True,
        attn_chunk_q=32,
        attn_chunk_kv=32,
        source="reduced smoke variant",
    )


register("qwen2.5-14b", full, reduced)
