"""Quickstart: train a FENIX traffic classifier and classify flows.

  PYTHONPATH=src python examples/quickstart.py

Covers the public API end to end in ~a minute: synthetic traffic, the
FENIX-CNN model, INT8 quantization for the Model Engine, and inference.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.configs.fenix_models import fenix_cnn
from repro.data.synthetic_traffic import (make_flows, task_meta,
                                          windows_from_flows,
                                          train_test_split)
from repro.models import traffic
from repro.quant.quantize import int8_apply, quantize_traffic
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig, batch_iterator


def main():
    classes, _ = task_meta("iscx")
    print("1) generating synthetic VPN-style traffic...")
    flows = make_flows("iscx", 300, seed=0, min_per_class=15)
    x, y, f = windows_from_flows(flows)
    (xtr, ytr, _), (xte, yte, _) = train_test_split(x, y, f)
    print(f"   {len(flows)} flows -> {len(y)} feature windows")

    print("2) training FENIX-CNN (float)...")
    cfg = fenix_cnn(len(classes))
    params = traffic.init(cfg, seed=0)
    trainer = Trainer(lambda p, b: traffic.loss_fn(p, cfg, b), params,
                      TrainerConfig(total_steps=250, log_every=50,
                                    opt=OptConfig(lr=3e-3, warmup_steps=25,
                                                  total_steps=250)))
    metrics = trainer.run(batch_iterator(xtr, ytr, 256))
    print(f"   final train loss {metrics['loss']:.3f}")

    print("3) INT8 post-training quantization (Model Engine deploy)...")
    qp = quantize_traffic(trainer.params, cfg, jnp.asarray(xtr[:512]))

    print("4) integer-only inference...")
    logits = int8_apply(qp, cfg, jnp.asarray(xte))
    pred = np.argmax(np.asarray(logits), -1)
    acc = float(np.mean(pred == yte))
    print(f"   held-out window accuracy (INT8): {acc:.3f}")
    for c, nm in enumerate(classes):
        m = yte == c
        if m.sum():
            print(f"     {nm:8s} acc={float(np.mean(pred[m]==c)):.3f} "
                  f"(n={int(m.sum())})")


if __name__ == "__main__":
    main()
