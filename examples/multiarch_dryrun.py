"""Example 4: lower+compile any assigned arch on the production mesh and
print its roofline terms — the multi-pod dry-run as a 10-line script.

  PYTHONPATH=src python examples/multiarch_dryrun.py --arch llama3.2-1b \
      --shape decode_32k --mesh multi
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()
    # dryrun must own jax initialization (512 host devices)
    from repro.launch.dryrun import run_cell
    res = run_cell(args.arch, args.shape, args.mesh, {}, {})
    print(f"status: {res['status']}  chips: {res.get('chips')}")
    if res["status"] != "ok":
        print(res.get("reason", res))
        return
    mem = res["memory"]
    print(f"compile: {res['compile_s']}s, HLO lines: {res['hlo_lines']}")
    print(f"per-device bytes: args {mem['argument_bytes']/1e9:.2f} GB, "
          f"temp {mem['temp_bytes']/1e9:.2f} GB")
    print(f"per-device flops: {res['cost']['flops']:.3e}")
    print(f"collectives: {res['collectives']['per_op']}")


if __name__ == "__main__":
    main()
