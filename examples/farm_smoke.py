"""2-pipe x 2-engine Model-Engine farm smoke (CI, 8 virtual devices).

Exercises the real 2-D (pipe x engine) ``shard_map`` path end-to-end:
builds a small deterministic trace, runs it through
``FenixConfig(num_pipes=2, num_engines=2)``, and asserts the farm
invariants — the mesh was actually used, every verdict matches the
nested-vmap fallback, service is accounted per engine, and the router
never dropped a lane at engine ingress.

Run on CPU with virtual devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/farm_smoke.py
"""

import numpy as np

import jax

from repro.core.fenix import FenixConfig, FenixSystem
from repro.core.model_engine.inference import ByLenModel
from repro.data.synthetic_traffic import uniform_flow_stream


def main() -> None:
    print(f"devices: {jax.device_count()}")
    stream = uniform_flow_stream(2048, 48, gap_us=100)
    def mk():
        return FenixSystem(
            FenixConfig(batch_size=256, control_plane_every=4,
                        num_pipes=2, num_engines=2), ByLenModel())

    sys_mesh = mk()
    assert sys_mesh._mesh is not None, (
        "2-pipe x 2-engine farm needs >= 4 devices; set "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    assert sys_mesh._mesh.devices.shape == (2, 2)
    assert sys_mesh._mesh.axis_names == ("pipe", "engine")
    v_mesh = sys_mesh.run_trace(stream)["verdict"]

    sys_vmap = mk()
    sys_vmap._mesh = None                     # nested-vmap reference
    v_vmap = sys_vmap.run_trace(stream)["verdict"]

    np.testing.assert_array_equal(v_mesh, v_vmap)
    assert sys_mesh.stats == sys_vmap.stats
    st = sys_mesh.stats
    assert st["inferences"] > 0
    assert sum(st["served_per_engine"]) == st["inferences"]
    assert min(st["served_per_engine"]) > 0   # both engines served
    assert st["dropped_eq"] == 0              # capacity-aware router
    print(f"verdicts classified: {(v_mesh >= 0).sum()}/{len(v_mesh)}")
    print(f"served_per_engine: {st['served_per_engine']}")
    print("2-pipe x 2-engine shard_map farm == vmap fallback: OK")


if __name__ == "__main__":
    main()
