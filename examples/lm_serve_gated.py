"""FENIX's technique applied to LM serving (deliverable b, example 3):

INT8-quantized weights (Model Engine) + probabilistic token-bucket
admission (Data Engine) in front of a llama3.2-style decoder.

  PYTHONPATH=src python examples/lm_serve_gated.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.serve.engine import ServeConfig, ServingEngine


def main():
    cfg = get_config("llama3.2-1b", reduced=True)
    params, _ = api.init_params(cfg, seed=0)
    rng = np.random.default_rng(0)

    print("float vs INT8 serving:")
    for quant in ("none", "int8"):
        eng = ServingEngine(cfg, dict(params),
                            ServeConfig(max_new_tokens=16, quant=quant))
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)}
        out = eng.generate(batch)
        print(f"  quant={quant:5s} decode {out['decode_tok_per_s']:.1f} "
              f"tok/s  first tokens {np.asarray(out['tokens'])[0][:6]}")

    print("gated admission (2 tenants, one 10x faster):")
    eng = ServingEngine(cfg, dict(params),
                        ServeConfig(max_new_tokens=4, quant="int8",
                                    gate_backend_rate=200.0))
    arrivals = []
    t = 0
    for i in range(40):
        t += int(rng.exponential(3000))
        sid = 0 if rng.random() < 10 / 11 else 1
        arrivals.append({"stream": sid, "t_us": t, "batch": {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)),
                                  jnp.int32)}})
    out = eng.serve_requests(arrivals)
    print(f"  admitted {out['admitted']} / denied {out['denied']} "
          f"(gate keeps the slow tenant served — Appendix A fairness)")


if __name__ == "__main__":
    main()
