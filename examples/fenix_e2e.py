"""End-to-end FENIX driver (deliverable b): train the traffic DNN for a few
hundred steps, deploy it INT8 on the Model Engine, and push a live packet
trace through the full switch+FPGA co-simulation.

  PYTHONPATH=src python examples/fenix_e2e.py [--packets 30000]

Prints the Data-Engine telemetry (grants, probability denials, bucket
denials, queue drops), the Model-Engine inference count, and per-packet /
per-flow accuracy of the deployed system.
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.baselines.common import flow_vote, macro_f1
from repro.configs.fenix_models import fenix_rnn
from repro.core.data_engine.decision_tree import fit_tree, tree_arrays
from repro.core.fenix import FenixConfig, FenixSystem
from repro.core.model_engine.inference import EngineModel
from repro.data.synthetic_traffic import (make_flows, packet_stream,
                                          windows_from_flows)
from repro.models import traffic
from repro.quant.quantize import quantize_traffic
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig, batch_iterator


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--packets", type=int, default=30_000)
    ap.add_argument("--flows", type=int, default=300)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--exact", action="store_true",
                    help="per-packet lax.scan data plane (slower, exact)")
    args = ap.parse_args()

    print("=== FENIX end-to-end ===")
    print("1) train FENIX-RNN on historical traffic...")
    train_flows = make_flows("iscx", args.flows, seed=0, min_per_class=15)
    x, y, _ = windows_from_flows(train_flows)
    cfg = fenix_rnn(7)
    params = traffic.init(cfg, 0)
    trainer = Trainer(lambda p, b: traffic.loss_fn(p, cfg, b), params,
                      TrainerConfig(total_steps=args.steps, log_every=100,
                                    opt=OptConfig(lr=3e-3,
                                                  warmup_steps=30,
                                                  total_steps=args.steps)))
    trainer.run(batch_iterator(x, y, 256))

    print("2) quantize to INT8 + load onto the Model Engine...")
    qp = quantize_traffic(trainer.params, cfg, jnp.asarray(x[:512]))
    model = EngineModel(cfg, qp)
    tree = tree_arrays(fit_tree(x[:, -1, :], y, depth=4, num_classes=7))

    print("3) replay a live trace through switch + FPGA...")
    live_flows = make_flows("iscx", args.flows, seed=7, min_per_class=15)
    stream = packet_stream(live_flows, limit=args.packets)
    oracle = [np.stack([f.pkt_len, f.ipd_us], -1).astype(np.int32)
              for f in live_flows]
    system = FenixSystem(FenixConfig(driver="host" if args.exact
                                     else "device",
                                     exact=args.exact), model,
                         tree=tree, oracle_windows=oracle)
    t0 = time.time()
    out = system.run_trace(stream)
    wall = time.time() - t0

    v, lab, fidx = out["verdict"], stream["label"], stream["flow_idx"]
    mask = v >= 0
    pkt_acc = float(np.mean(v[mask] == lab[mask]))
    uf, votes = flow_vote(v[mask], fidx[mask])
    flow_labels = np.asarray([lab[fidx == f][0] for f in uf])
    print(f"   processed {len(v)} packets in {wall:.1f}s "
          f"({len(v)/wall/1e3:.0f} kpps simulated)")
    print(f"   data engine: {system.stats}")
    print(f"   verdict coverage {mask.mean():.3f}")
    print(f"   per-packet accuracy {pkt_acc:.3f}")
    print(f"   flow macro-F1 {macro_f1(flow_labels, votes, 7):.3f}")


if __name__ == "__main__":
    main()
