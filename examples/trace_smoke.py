"""Trace-replay CI smoke: pcap fixtures -> streaming ingest -> 2-pipe
driver.

Builds (or reuses, when the CI fixture cache hits) the deterministic pcap
fixtures under ``benchmarks/fixtures`` via ``synthesize_pcap``, proves the
``pcap -> ingest -> packet_stream`` round trip is bit-identical to the
regenerated source stream — which validates cached fixture bytes against
the current generator — and replays the capture through the 2-pipeline
sharded driver with ``run_trace(<pcap path>)``.

Run on CPU (2 virtual devices exercise the real pipe mesh; 1 falls back
to vmap with identical semantics):

    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
        PYTHONPATH=src python examples/trace_smoke.py

Set ``TRACE_FIXTURE_DIR`` to redirect where fixtures live (the CI job
caches that directory keyed on a hash of the generator sources).
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from benchmarks.bench_traces import build_fixture
from repro.core.fenix import FenixConfig, FenixSystem
from repro.core.model_engine.inference import ByLenModel
from repro.data import trace_ingest as ti


def main() -> None:
    print(f"devices: {jax.device_count()}")
    pcap = build_fixture()          # writes or cache-validates, then
    size = os.path.getsize(pcap)    # asserts round-trip bit-identity
    print(f"fixture: {pcap} ({size} bytes) — round-trip oracle OK")

    stream = ti.ingest_pcap(pcap)
    n = len(stream["ts_us"])
    assert n > 0 and (stream["label"] >= 0).all(), \
        "fixture sidecar labels missing"

    sys_ = FenixSystem(
        FenixConfig(batch_size=512, control_plane_every=4, num_pipes=2),
        ByLenModel())
    out = sys_.run_trace(pcap)
    v = out["verdict"]
    st = sys_.stats
    assert st["packets"] == n, (st["packets"], n)
    assert st["granted"] > 0 and st["inferences"] > 0
    assert (v >= 0).sum() > 0, "no packet ever classified"
    assert st["dropped_inflight"] == 0

    # the pcap path and the in-memory stream must drive the same verdicts
    sys_ref = FenixSystem(
        FenixConfig(batch_size=512, control_plane_every=4, num_pipes=2),
        ByLenModel())
    v_ref = sys_ref.run_trace(stream)["verdict"]
    np.testing.assert_array_equal(v, v_ref)

    print(f"replayed {n} packets through num_pipes=2 "
          f"(sharded={sys_._mesh is not None}): granted={st['granted']} "
          f"inferences={st['inferences']} "
          f"classified={(v >= 0).sum()}/{n}")
    print("trace-replay smoke OK")


if __name__ == "__main__":
    main()
