"""The ``FenixConfig(driver=...)`` selector and its deprecation shim.

The pre-driver= API selected the trace driver through four interacting
booleans (``fast_mode``/``device_path``/``pipes_path``/``farm_path``).
This suite pins the redesign's contract:

* every legacy boolean combination (the full 4-bool cube, all 16 combos
  explicitly passed) resolves to the same driver as its ``driver=``
  equivalent — or raises the same conflict error the new API defines;
* the shim warns with ``DeprecationWarning`` exactly once per construct
  (and ``FenixSystem``'s internal ``dataclasses.replace`` does not
  re-warn);
* conflicting knob combinations raise ``ValueError`` messages that name
  the ``driver=`` spelling, not the deprecated booleans;
* the device-family drivers replay traces with zero host-driven
  control-plane syncs (``FenixSystem.host_syncs``) while the host oracle
  syncs once per T_w window;
* ``run_trace``'s legacy keyword pile (``stream=``/``source=``/...) maps
  onto ``trace=`` with a deprecation warning.

This file and the shim itself are the only places in the repo allowed to
spell the deprecated kwargs (enforced by tools/check_deprecated.py).
"""

import itertools
import os
import tempfile
import warnings

import numpy as np
import pytest

from repro.core.fenix import FenixConfig, FenixSystem, TraceSpec
from repro.core.model_engine.inference import ByLenModel
from repro.data import trace_ingest as ti
from repro.data.synthetic_traffic import make_flows, packet_stream

LEGACY = ("fast_mode", "device_path", "pipes_path", "farm_path")


def _legacy_expectation(fm, dp, pp, fp):
    """The old boolean-cube resolution, spelled as (driver, exact) or
    ValueError for the combos the redesign (correctly) rejects."""
    if (pp or fp) and not (fm and dp):
        return ValueError
    if fp:
        return ("farm", False)
    if pp:
        return ("pipes", False)
    if fm and dp:
        return ("device", False)
    return ("host", not fm)


@pytest.mark.parametrize("fm,dp,pp,fp", list(itertools.product(
    (False, True), repeat=4)))
def test_legacy_cube_resolves_like_driver_equivalent(fm, dp, pp, fp):
    """Property over the whole 4-bool cube: the shim lands on exactly the
    driver/exact pair the new spelling produces (or both reject)."""
    expect = _legacy_expectation(fm, dp, pp, fp)
    if expect is ValueError:
        with pytest.raises(ValueError, match="driver"):
            FenixConfig(fast_mode=fm, device_path=dp, pipes_path=pp,
                        farm_path=fp)
        return
    driver, exact = expect
    with pytest.warns(DeprecationWarning):
        legacy = FenixConfig(fast_mode=fm, device_path=dp, pipes_path=pp,
                             farm_path=fp)
    modern = FenixConfig(driver=driver, exact=exact)
    assert (legacy.driver, legacy.exact) == (modern.driver, modern.exact)
    # the legacy fields are normalized away after resolution
    assert all(getattr(legacy, k) is None for k in LEGACY)


def test_auto_resolution():
    assert FenixConfig().driver == "device"
    assert FenixConfig(exact=True).driver == "host"
    assert FenixConfig(num_pipes=2).driver == "pipes"
    assert FenixConfig(num_engines=2).driver == "farm"
    assert FenixConfig(num_pipes=2, num_engines=2).driver == "farm"


def test_shim_warns_exactly_once_per_construct():
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        FenixConfig(device_path=False)
    assert len([w for w in rec
                if issubclass(w.category, DeprecationWarning)]) == 1
    # a resolved config re-entering __post_init__ (dataclasses.replace
    # inside FenixSystem, e.g. for gate_backend folding) must not re-warn
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        with pytest.warns(DeprecationWarning):
            cfg = FenixConfig(batch_size=64, device_path=False,
                              gate_backend="ref")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        FenixSystem(cfg, ByLenModel())
    assert not [w for w in rec
                if issubclass(w.category, DeprecationWarning)]


def test_conflicting_knobs_raise_with_driver_spelling():
    # the old farm_path=False + num_engines>1 bug, now caught up front
    with pytest.raises(ValueError, match=r'driver="farm"'):
        FenixConfig(num_engines=2, farm_path=False)
    with pytest.raises(ValueError, match=r'driver="farm"'):
        FenixConfig(num_engines=2, driver="device")
    with pytest.raises(ValueError, match=r'driver="pipes"'):
        FenixConfig(num_pipes=2, driver="host")
    # scan (exact) admission off the host loop
    with pytest.raises(ValueError, match=r'driver="host"'):
        FenixConfig(exact=True, driver="device")
    with pytest.raises(ValueError, match=r'driver="pipes"\|"farm"'):
        FenixConfig(num_pipes=2, fast_mode=False)
    with pytest.raises(ValueError, match="unknown driver"):
        FenixConfig(driver="gpu")
    with pytest.raises(ValueError, match="not both"):
        FenixConfig(driver="device", device_path=True)


# ---------------------------------------------------------------------------
# zero host syncs on the device-family drivers
# ---------------------------------------------------------------------------

_B, _CPE, _N = 128, 2, 900


@pytest.fixture(scope="module")
def small_trace():
    return packet_stream(make_flows("iscx", 12, seed=5), limit=_N)


@pytest.mark.parametrize("driver", ("device", "pipes", "farm"))
def test_device_drivers_run_with_zero_host_syncs(small_trace, driver):
    sys_ = FenixSystem(FenixConfig(batch_size=_B, control_plane_every=_CPE,
                                   driver=driver), ByLenModel())
    sys_.run_trace(dict(small_trace))
    assert sys_.host_syncs == 0
    assert sys_.stats["packets"] == _N


def test_host_oracle_syncs_once_per_window(small_trace):
    sys_ = FenixSystem(FenixConfig(batch_size=_B, control_plane_every=_CPE,
                                   driver="host"), ByLenModel())
    sys_.run_trace(dict(small_trace))
    n_batches = -(-_N // _B)
    assert sys_.host_syncs == n_batches // _CPE > 0


# ---------------------------------------------------------------------------
# run_trace(trace=...) and its deprecated keyword pile
# ---------------------------------------------------------------------------


def test_run_trace_stream_kwarg_deprecated(small_trace):
    sys_ = FenixSystem(FenixConfig(batch_size=_B), ByLenModel())
    with pytest.warns(DeprecationWarning, match="deprecated"):
        out = sys_.run_trace(stream=dict(small_trace))
    assert len(out["verdict"]) == _N


def test_run_trace_source_kwarg_deprecated(small_trace):
    with tempfile.TemporaryDirectory() as tmp:
        pcap = os.path.join(tmp, "t.pcap")
        ti.write_pcap(small_trace, pcap)
        sys_ = FenixSystem(FenixConfig(batch_size=_B), ByLenModel())
        with pytest.warns(DeprecationWarning, match="deprecated"):
            out = sys_.run_trace(source=pcap, limit=256)
        assert len(out["verdict"]) == 256


def test_run_trace_needs_exactly_one_trace(small_trace):
    sys_ = FenixSystem(FenixConfig(batch_size=_B), ByLenModel())
    with pytest.raises(ValueError, match="exactly one trace"):
        sys_.run_trace()
    with pytest.raises(ValueError, match="exactly one trace"):
        with pytest.warns(DeprecationWarning):
            sys_.run_trace(dict(small_trace), stream=dict(small_trace))


def test_run_trace_positional_dict_does_not_warn(small_trace):
    sys_ = FenixSystem(FenixConfig(batch_size=_B), ByLenModel())
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        sys_.run_trace(dict(small_trace))
    assert not [w for w in rec
                if issubclass(w.category, DeprecationWarning)]


def test_run_trace_tracespec_streaming_matches_dict(small_trace):
    """TraceSpec over a dict source streams through the double-buffered
    driver and reproduces the in-memory replay bit-for-bit."""
    ref = FenixSystem(FenixConfig(batch_size=_B, control_plane_every=_CPE),
                      ByLenModel())
    v_ref = ref.run_trace(dict(small_trace))["verdict"]
    for overlap in (True, False):
        sys_ = FenixSystem(FenixConfig(batch_size=_B,
                                       control_plane_every=_CPE),
                           ByLenModel())
        spec = TraceSpec(dict(small_trace), chunk_pkts=300,
                         overlap=overlap)
        v = sys_.run_trace(spec)["verdict"]
        np.testing.assert_array_equal(v, v_ref)
        assert sys_.host_syncs == 0
        assert sys_.stats == ref.stats
