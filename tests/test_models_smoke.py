"""REQUIRED per-arch smoke tests: reduced config, one forward/train step on
CPU, assert output shapes + no NaNs.  One test per assigned architecture."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import api
from repro.train import optimizer as opt_lib


def _batch(cfg, rng, b=2, s=32):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
    }
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(
            rng.normal(0, 1, (b, s, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(0, 1, (b, cfg.num_image_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(list_archs()))
def test_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    rng = np.random.default_rng(0)
    params, axes = api.init_params(cfg, seed=0)
    assert set(params) == set(axes)
    batch = _batch(cfg, rng)
    loss, metrics = api.loss_fn(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    # one optimizer step moves the loss
    ocfg = opt_lib.OptConfig(lr=1e-2, warmup_steps=0, total_steps=10,
                             weight_decay=0.0)
    step = jax.jit(opt_lib.make_train_step(
        lambda p, b: api.loss_fn(p, cfg, b), ocfg))
    opt = opt_lib.init_state(params)
    p2, o2, m = step(params, opt, batch)
    assert bool(jnp.isfinite(m["loss"]))
    loss2, _ = api.loss_fn(p2, cfg, batch)
    assert float(loss2) < float(loss), f"{arch}: step did not reduce loss"


@pytest.mark.parametrize("arch", sorted(list_archs()))
def test_decode_shapes(arch):
    cfg = get_config(arch, reduced=True)
    rng = np.random.default_rng(1)
    params, _ = api.init_params(cfg, seed=0)
    b, s = 2, 16
    batch = _batch(cfg, rng, b=b, s=s)
    batch.pop("labels")
    cache, logits = api.prefill(params, cfg, batch)
    assert logits.shape == (b, cfg.vocab_size)
    cache = api.grow_cache(cfg, cache, b, s, s + 4,
                           src_len=s if cfg.family == "encdec" else None)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    cache2, logits2 = api.decode_step(params, cfg, cache, tok)
    assert logits2.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1
