"""Device-resident trace driver vs the host reference loop.

The tentpole invariant: the jitted ``lax.scan`` path (device FIFO, array
delay line, in-scan Model-Engine service) produces bit-identical verdicts
and stats to the original batch-at-a-time Python loop.  Also covers the
jittable Vector I/O ops against the host oracle and the delay line against
the Python-list in-flight semantics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.fenix_models import fenix_cnn
from repro.core.data_engine.decision_tree import fit_tree, tree_arrays
from repro.core.data_engine.state import EngineConfig, init_state
from repro.core.fenix import FenixConfig, FenixSystem
from repro.core.model_engine import delay_line as dl
from repro.core.model_engine import vector_io as vio
from repro.core.model_engine.inference import EngineModel
from repro.data.synthetic_traffic import (make_flows, packet_stream,
                                          windows_from_flows)
from repro.models import traffic
from repro.quant.quantize import quantize_traffic

I32 = jnp.int32


# -- Vector I/O: device ops == host oracle ----------------------------------

def test_enqueue_dequeue_device_matches_host():
    cfg = vio.IOConfig(queue_len=16)
    rng = np.random.default_rng(0)
    qh = vio.init_queues(cfg)
    qd = vio.init_queues(cfg)
    for step in range(30):
        n = int(rng.integers(1, 12))
        valid = rng.random(n) < 0.7
        slots = rng.integers(0, 100, n).astype(np.int32)
        hashes = rng.integers(1, 2**31, n).astype(np.uint32)
        feats = rng.integers(0, 50, (n, cfg.feat_len, cfg.feat_dim)
                             ).astype(np.int32)
        qh = vio.enqueue_batch(qh, cfg, slots[valid], hashes[valid],
                               feats[valid])
        qd = vio.enqueue_device(qd, cfg, jnp.asarray(valid),
                                jnp.asarray(slots), jnp.asarray(hashes),
                                jnp.asarray(feats))
        budget = int(rng.integers(0, 10))
        qh, s1, h1, f1 = vio.dequeue_batch(qh, cfg, budget)
        qd, s2, h2, f2, cnt = vio.dequeue_device(qd, cfg,
                                                 jnp.asarray(budget))
        cnt = int(cnt)
        assert cnt == len(s1), step
        assert (np.asarray(s2)[:cnt] == s1).all()
        assert (np.asarray(h2)[:cnt] == h1).all()
        assert (np.asarray(f2)[:cnt] == f1).all()
        assert int(qh["dropped"]) == int(qd["dropped"])
        assert vio.occupancy(qh) == vio.occupancy(qd)


def test_dequeue_device_respects_serve_lanes_cap():
    cfg = vio.IOConfig(queue_len=32, serve_max=4)
    q = vio.init_queues(cfg)
    n = 10
    q = vio.enqueue_device(q, cfg, jnp.ones(n, bool),
                           jnp.arange(n, dtype=I32),
                           jnp.arange(1, n + 1, dtype=jnp.uint32),
                           jnp.zeros((n, cfg.feat_len, cfg.feat_dim), I32))
    q, s, h, f, cnt = vio.dequeue_device(q, cfg, jnp.asarray(100))
    assert int(cnt) == 4 and s.shape == (4,)
    assert list(np.asarray(s)) == [0, 1, 2, 3]


# -- delay line == Python-list in-flight semantics ---------------------------

def _list_deliver(state, inflight, now):
    """The legacy FenixSystem._deliver, as a pure oracle."""
    from repro.core.data_engine import flow_tracker as ft
    remain = []
    for (t, slot, h, cls) in inflight:
        if t <= now:
            state = ft.apply_inference_result(
                state, jnp.asarray(slot), jnp.asarray(cls),
                jnp.asarray(h, jnp.uint32))
        else:
            remain.append((t, slot, h, cls))
    return state, remain


def test_delay_line_matches_python_list():
    """Jitted delivery == sequential list: ordering, hash check, last-wins."""
    cfg = EngineConfig(n_slots_log2=6)
    rng = np.random.default_rng(1)
    state_a = init_state(cfg)
    state_b = init_state(cfg)
    # flow table with 20 occupied slots
    slots = rng.choice(cfg.n_slots, 20, replace=False).astype(np.int32)
    hashes = rng.integers(1, 2**31, 20).astype(np.uint32)
    for st in (state_a, state_b):
        st["hash"] = st["hash"].at[jnp.asarray(slots)].set(
            jnp.asarray(hashes))
    dline = dl.init(64)
    inflight = []
    deliver_jit = jax.jit(dl.deliver, static_argnames=("n_slots",))
    now = 0
    for rounds in range(6):
        # push a batch with duplicate slots and some stale hashes
        k = int(rng.integers(1, 8))
        pick = rng.integers(0, 20, k)
        s = slots[pick]
        h = hashes[pick].copy()
        stale = rng.random(k) < 0.3
        h[stale] += 1                      # evicted-flow results must drop
        cls = rng.integers(0, 7, k).astype(np.int32)
        t_del = now + int(rng.integers(1, 30))
        inflight += [(t_del, int(s[i]), int(h[i]), int(cls[i]))
                     for i in range(k)]
        dline = dl.push(dline, jnp.asarray(t_del, I32), jnp.asarray(s, I32),
                        jnp.asarray(h, jnp.uint32), jnp.asarray(cls, I32),
                        jnp.asarray(k, I32))
        now += int(rng.integers(0, 40))
        state_a, inflight = _list_deliver(state_a, inflight, now)
        state_b, dline = deliver_jit(state_b, dline, jnp.asarray(now, I32),
                                     n_slots=cfg.n_slots)
        assert (np.asarray(state_a["cls"])
                == np.asarray(state_b["cls"])).all(), rounds
        assert len(inflight) == int(dline["tail"]) - int(dline["head"])


# -- full system: device scan == host loop ----------------------------------

@pytest.fixture(scope="module")
def small_system():
    flows = make_flows("iscx", 50, seed=11)
    x, y, _ = windows_from_flows(flows)
    cfg = fenix_cnn(7)
    params = traffic.init(cfg, 0)       # untrained: fidelity is not at stake
    qp = quantize_traffic(params, cfg, jnp.asarray(x[:128]))
    model = EngineModel(cfg, qp)
    tree = tree_arrays(fit_tree(x[:, -1, :], y, depth=4, num_classes=7))
    stream = packet_stream(flows, limit=3000)
    oracle = [np.stack([f.pkt_len, f.ipd_us], -1).astype(np.int32)
              for f in flows]
    return model, tree, stream, oracle


def _fresh(model, tree, oracle, device, batch_size=512, cpe=3):
    return FenixSystem(
        FenixConfig(batch_size=batch_size, control_plane_every=cpe,
                    driver="device" if device else "host"),
        model, tree=tree, oracle_windows=oracle)


def test_device_trace_matches_host_loop(small_system):
    model, tree, stream, oracle = small_system
    sys_d = _fresh(model, tree, oracle, device=True)
    sys_h = _fresh(model, tree, oracle, device=False)
    vd = sys_d.run_trace(stream)["verdict"]
    vh = sys_h.run_trace(stream)["verdict"]
    assert sys_d.stats == sys_h.stats
    assert (vd == vh).all()
    assert sys_d.stats["inferences"] > 0
    assert sys_d.stats["granted"] > 0


def test_device_trace_matches_host_loop_no_oracle_no_tree(small_system):
    model, _, stream, _ = small_system
    sys_d = _fresh(model, None, None, device=True, batch_size=256, cpe=4)
    sys_h = _fresh(model, None, None, device=False, batch_size=256, cpe=4)
    vd = sys_d.run_trace(stream)["verdict"]
    vh = sys_h.run_trace(stream)["verdict"]
    assert sys_d.stats == sys_h.stats
    assert (vd == vh).all()


def test_device_trace_uneven_tail_batch(small_system):
    """Remainder chunk (n % batch_size != 0) goes through the same path."""
    model, tree, stream, oracle = small_system
    cut = {k: v[:1234] for k, v in stream.items()}
    sys_d = _fresh(model, tree, oracle, device=True, batch_size=500)
    sys_h = _fresh(model, tree, oracle, device=False, batch_size=500)
    vd = sys_d.run_trace(cut)["verdict"]
    vh = sys_h.run_trace(cut)["verdict"]
    assert len(vd) == 1234
    assert sys_d.stats == sys_h.stats
    assert (vd == vh).all()


def test_step_after_device_trace_interops(small_system):
    """Host step() after a device run drains the device delay line."""
    model, tree, stream, oracle = small_system
    sys_ = _fresh(model, tree, oracle, device=True, batch_size=512)
    first = {k: v[:2048] for k, v in stream.items()}
    sys_.run_trace(first)
    rest = {k: v[2048:2560] for k, v in stream.items()}
    out = sys_.step(rest)
    assert len(out["verdict"]) == 512
    assert sys_.stats["packets"] == 2560
