"""Tier-1 split audit: the PR gate (-m "not slow") plus the slow set must
cover EXACTLY the full suite — a marker typo or a bad -m expression can
otherwise silently drop tests from CI.

Collects three counts (full, not-slow, slow) via pytest's own collection
and fails unless full == not_slow + slow.  Prints the counts so the CI
log records what each tier runs.

    python -m tests.check_split
"""

from __future__ import annotations

import re
import subprocess
import sys

_COLLECTED = re.compile(r"(\d+)(?:/\d+)? tests? collected", re.M)
_EMPTY = re.compile(r"no tests ran|(\d+) deselected", re.M)


def collect_count(marker_expr: str | None = None) -> int:
    cmd = [sys.executable, "-m", "pytest", "--collect-only", "-q"]
    if marker_expr:
        cmd += ["-m", marker_expr]
    proc = subprocess.run(cmd, capture_output=True, text=True)
    out = proc.stdout + proc.stderr
    if proc.returncode not in (0, 5):       # 5 = nothing collected
        sys.stderr.write(out)
        raise SystemExit(f"collection failed (exit {proc.returncode}) "
                         f"for -m {marker_expr!r}")
    m = _COLLECTED.search(out)
    if m:
        return int(m.group(1))
    if proc.returncode == 5 or _EMPTY.search(out):
        return 0
    sys.stderr.write(out)
    raise SystemExit(f"could not parse collection count for "
                     f"-m {marker_expr!r}")


def main() -> int:
    full = collect_count()
    fast = collect_count("not slow")
    slow = collect_count("slow")
    print(f"tier-1 split: full={full}  pr-gate(not slow)={fast}  "
          f"scheduled-extra(slow)={slow}")
    if full != fast + slow:
        print(f"SPLIT MISMATCH: {fast} + {slow} != {full} — some tests "
              "are in neither tier (bad marker expression or collection "
              "divergence)")
        return 1
    if fast == 0:
        print("SPLIT MISMATCH: PR gate collects zero tests")
        return 1
    print("split covers the full suite")
    return 0


if __name__ == "__main__":
    sys.exit(main())
