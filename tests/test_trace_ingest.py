"""Real-trace ingestion: pcap round-trip bit-identity (the subsystem's
correctness oracle), malformed-capture errors, CSV adapter label mapping,
and chunked-vs-whole iteration equivalence."""

import io
import os
import struct
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data import trace_formats as tf
from repro.data import trace_ingest as ti
from repro.data.synthetic_traffic import (make_flows, packet_stream,
                                          task_meta, uniform_flow_stream)
from repro.data.trace_formats import TraceFormatError


def _roundtrip(flows, tmp, limit=None, **kw):
    pcap = os.path.join(tmp, "t.pcap")
    oracle = ti.synthesize_pcap(flows, pcap, limit=limit, **kw)
    return oracle, ti.ingest_pcap(pcap), pcap


# ---------------------------------------------------------------------------
# pcap round trip — the bit-identity property
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(n_flows=st.integers(3, 28), seed=st.integers(0, 10_000),
       task=st.sampled_from(("iscx", "ustc")))
def test_pcap_roundtrip_bit_identity(n_flows, seed, task):
    """pcap -> ingest -> packet_stream == source stream, every column,
    every dtype, bit for bit (with the ground-truth sidecar)."""
    flows = make_flows(task, n_flows, seed=seed)
    with tempfile.TemporaryDirectory() as tmp:
        oracle, got, _ = _roundtrip(flows, tmp, limit=3000)
        assert sorted(got) == sorted(oracle)
        for k in oracle:
            assert got[k].dtype == oracle[k].dtype, k
            np.testing.assert_array_equal(got[k], oracle[k], err_msg=k)


def test_pcap_roundtrip_without_sidecar():
    flows = make_flows("iscx", 12, seed=4)
    with tempfile.TemporaryDirectory() as tmp:
        pcap = os.path.join(tmp, "t.pcap")
        oracle = ti.synthesize_pcap(pcap_path=pcap, flows=flows,
                                    labels_path=None)
        got = ti.ingest_pcap(pcap)
        for k in ti.PKT_COLS:       # data-plane keys still bit-identical
            np.testing.assert_array_equal(got[k], oracle[k], err_msg=k)
        assert (got["label"] == -1).all()
        # first-seen flow numbering keeps per-flow positions intact
        np.testing.assert_array_equal(got["flow_pos"], oracle["flow_pos"])


def test_pcap_roundtrip_nanosecond_bigendian():
    flows = make_flows("ustc", 8, seed=2)
    with tempfile.TemporaryDirectory() as tmp:
        pcap = os.path.join(tmp, "t.pcap")
        oracle = packet_stream(flows, limit=800)
        ti.write_pcap(oracle, pcap, nanos=True, byteorder=">")
        got = ti.ingest_pcap(pcap, labels=None)
        for k in ti.PKT_COLS:
            np.testing.assert_array_equal(got[k], oracle[k], err_msg=k)


def test_non_l4_protocols_lose_ports_only():
    """Protocols without TCP/UDP headers cannot carry ports in a real
    capture; everything else still round-trips exactly."""
    st_ = uniform_flow_stream(1500, 40, seed=3)
    with tempfile.TemporaryDirectory() as tmp:
        pcap = os.path.join(tmp, "t.pcap")
        ti.write_pcap(st_, pcap)
        got = ti.ingest_pcap(pcap, labels=None)
        l4 = np.isin(st_["proto"], (6, 17))
        assert 0 < l4.sum() < len(l4)       # stream mixes both kinds
        for k in ("ts_us", "pkt_len", "src_ip", "dst_ip", "proto"):
            np.testing.assert_array_equal(
                got[k], st_[k].astype(ti.STREAM_DTYPES[k]), err_msg=k)
        for k in ("src_port", "dst_port"):
            np.testing.assert_array_equal(got[k][l4], st_[k][l4])
            assert (got[k][~l4] == 0).all()


def test_epoch_timestamps_rebase_to_first_record():
    """Real captures carry epoch microseconds far past int32; ingest
    rebases them to the first record like packet_stream's wrap."""
    st_ = uniform_flow_stream(64, 8, seed=1)
    epoch = 1_700_000_000 * 1_000_000
    shifted = dict(st_)
    shifted["ts_us"] = st_["ts_us"].astype(np.int64) + epoch
    with tempfile.TemporaryDirectory() as tmp:
        pcap = os.path.join(tmp, "t.pcap")
        ti.write_pcap(shifted, pcap)
        got = ti.ingest_pcap(pcap, labels=None)
        base = int(st_["ts_us"][0])
        np.testing.assert_array_equal(
            got["ts_us"], (st_["ts_us"] - base).astype(np.int32))


def test_chunked_vs_whole_file_equivalence():
    flows = make_flows("iscx", 20, seed=7)
    with tempfile.TemporaryDirectory() as tmp:
        pcap = os.path.join(tmp, "t.pcap")
        ti.synthesize_pcap(flows, pcap, limit=2500)
        whole = ti.ingest_pcap(pcap, chunk_pkts=1 << 20)
        for chunk_pkts in (7, 64, 999):
            part = ti.ingest_pcap(pcap, chunk_pkts=chunk_pkts)
            for k in whole:
                np.testing.assert_array_equal(
                    part[k], whole[k], err_msg=f"{k}@{chunk_pkts}")
        # sidecar-less numbering must also be chunk-size invariant
        whole_n = ti.ingest_pcap(pcap, labels=None, chunk_pkts=1 << 20)
        part_n = ti.ingest_pcap(pcap, labels=None, chunk_pkts=13)
        for k in whole_n:
            np.testing.assert_array_equal(part_n[k], whole_n[k],
                                          err_msg=k)


def test_ingest_limit_stops_reading():
    flows = make_flows("iscx", 10, seed=8)
    with tempfile.TemporaryDirectory() as tmp:
        oracle, _, pcap = _roundtrip(flows, tmp)
        got = ti.ingest_pcap(pcap, limit=123, chunk_pkts=50)
        assert len(got["ts_us"]) == 123
        for k in oracle:
            np.testing.assert_array_equal(got[k], oracle[k][:123],
                                          err_msg=k)


def test_duplicate_five_tuple_rejected():
    flows = make_flows("iscx", 4, seed=0)
    flows[2].five_tuple = flows[0].five_tuple
    with tempfile.TemporaryDirectory() as tmp:
        with pytest.raises(TraceFormatError, match="share 5-tuple"):
            ti.synthesize_pcap(flows, os.path.join(tmp, "t.pcap"))


def test_non_l4_flow_with_ports_rejected():
    """A flow whose protocol carries no L4 header but whose 5-tuple has
    nonzero ports could never round-trip (write_pcap drops the ports, so
    ingest could not match the sidecar) — synthesize must reject it
    instead of silently corrupting flow_idx/label."""
    flows = make_flows("iscx", 3, seed=1)
    ft = flows[1].five_tuple
    flows[1].five_tuple = (ft[0], ft[1], ft[2], ft[3], 1)   # ICMP + ports
    with tempfile.TemporaryDirectory() as tmp:
        with pytest.raises(TraceFormatError, match="only carries ports"):
            ti.synthesize_pcap(flows, os.path.join(tmp, "t.pcap"))
        # zero ports are fine: the wire identity is unambiguous
        flows[1].five_tuple = (ft[0], ft[1], 0, 0, 1)
        oracle = ti.synthesize_pcap(flows, os.path.join(tmp, "ok.pcap"))
        got = ti.ingest_pcap(os.path.join(tmp, "ok.pcap"))
        for k in oracle:
            np.testing.assert_array_equal(got[k], oracle[k], err_msg=k)


def test_load_stream_accepts_file_objects():
    flows = make_flows("iscx", 6, seed=2)
    with tempfile.TemporaryDirectory() as tmp:
        pcap = os.path.join(tmp, "t.pcap")
        oracle = ti.synthesize_pcap(flows, pcap, limit=400)
        with open(pcap, "rb") as f:
            got = ti.load_stream(f)
        for k in ti.PKT_COLS:
            np.testing.assert_array_equal(got[k], oracle[k], err_msg=k)
        with open(pcap, "rb") as f:
            assert len(ti.load_flows(f)) == len(
                np.unique(oracle["flow_idx"]))


# ---------------------------------------------------------------------------
# malformed captures
# ---------------------------------------------------------------------------


def _ingest_bytes(data: bytes):
    return ti.ingest_pcap(io.BytesIO(data), labels=None)


def test_empty_file_is_a_clear_error():
    with pytest.raises(TraceFormatError, match="empty pcap"):
        _ingest_bytes(b"")


def test_bad_magic_is_a_clear_error():
    with pytest.raises(TraceFormatError, match="bad pcap magic"):
        _ingest_bytes(b"\xde\xad\xbe\xef" + b"\x00" * 20)


def test_truncated_global_header():
    with pytest.raises(TraceFormatError, match="truncated pcap global"):
        _ingest_bytes(b"\xd4\xc3\xb2\xa1\x02\x00")


def test_truncated_record_header_and_body():
    flows = make_flows("iscx", 5, seed=1)
    with tempfile.TemporaryDirectory() as tmp:
        _, _, pcap = _roundtrip(flows, tmp, limit=50)
        raw = open(pcap, "rb").read()
        with pytest.raises(TraceFormatError,
                           match="truncated pcap record header"):
            _ingest_bytes(raw[:24 + 6])
        with pytest.raises(TraceFormatError,
                           match="truncated pcap record body"):
            _ingest_bytes(raw[:24 + 16 + 9])


def test_unsupported_linktype():
    hdr = struct.pack("<IHHiIII", ti.PCAP_MAGIC_US, 2, 4, 0, 0, 65535,
                      228)          # LINKTYPE_IPV4_WITH_PHB, unsupported
    with pytest.raises(TraceFormatError, match="unsupported pcap linktype"):
        _ingest_bytes(hdr)


def test_non_ip_frames_are_skipped_and_counted():
    flows = make_flows("iscx", 6, seed=3)
    with tempfile.TemporaryDirectory() as tmp:
        oracle, _, pcap = _roundtrip(flows, tmp, limit=40)
        raw = open(pcap, "rb").read()
        # splice an ARP frame (ethertype 0x0806) after the global header
        arp = b"\xff" * 12 + b"\x08\x06" + b"\x00" * 28
        rec = struct.pack("<IIII", 0, 0, len(arp), len(arp))
        stats = {}
        got = ti.ingest_pcap(
            io.BytesIO(raw[:24] + rec + arp + raw[24:]), labels=None,
            stats=stats)
        assert stats["skipped"] == 1
        assert len(got["ts_us"]) == len(oracle["ts_us"])


# ---------------------------------------------------------------------------
# CSV adapters
# ---------------------------------------------------------------------------


def test_generic_csv_roundtrip():
    flows = make_flows("ustc", 15, seed=6)
    oracle = packet_stream(flows, limit=1500)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "t.csv")
        ti.write_generic_csv(oracle, path)
        got = packet_stream(tf.flows_from_csv(path, "generic"))
        for k in oracle:
            np.testing.assert_array_equal(got[k], oracle[k], err_msg=k)
        # and through the front door / the drivers' source= selector
        got2 = ti.load_stream(path)
        for k in oracle:
            np.testing.assert_array_equal(got2[k], oracle[k], err_msg=k)


def test_label_mapping_exhaustive():
    """Every adapter alias resolves to a valid class index, and every
    class of the task vocabulary is reachable through some alias."""
    for schema in (tf.ISCX_VPN, tf.USTC_TFC, tf.GENERIC):
        for alias in schema.label_aliases:
            idx = tf.map_label(alias, schema)
            assert 0 <= idx < len(schema.classes), (schema.name, alias)
        assert set(schema.label_aliases.values()) == set(schema.classes), \
            schema.name
    # canonical class names map to their own index, vpn- prefixed too
    for task, schema in (("iscx", tf.ISCX_VPN), ("ustc", tf.USTC_TFC)):
        classes, _ = task_meta(task)
        assert schema.classes == classes
        for i, name in enumerate(classes):
            assert tf.map_label(name, schema) == i
    assert tf.map_label("VPN-Chat", tf.ISCX_VPN) == 0
    assert tf.map_label("Streaming", tf.ISCX_VPN) == 4


def test_unknown_label_strict_vs_lenient():
    with pytest.raises(TraceFormatError, match="known labels"):
        tf.map_label("quic-magic", tf.ISCX_VPN)
    assert tf.map_label("quic-magic", tf.ISCX_VPN, strict=False) == -1


def test_iscx_vpn_flow_level_adapter():
    text = (
        "Src IP,Src Port,Dst IP,Dst Port,Protocol,Timestamp,"
        "Flow Duration,Total Fwd Packets,"
        "Total Length of Fwd Packets,Label\n"
        "10.0.0.1,443,10.0.0.2,51000,TCP,12.5,2000000,10,14000,VPN-Chat\n"
        "192.168.1.5,5060,10.0.0.9,5061,UDP,13.0,5000000,50,8600,VoIP\n")
    flows = tf.flows_from_csv_text(text, "iscx_vpn")
    assert [f.label for f in flows] == [0, 5]
    f0 = flows[0]
    assert f0.five_tuple == ((10 << 24) + 1, (10 << 24) + 2, 443, 51000, 6)
    assert f0.start_us == 12_500_000
    assert len(f0.pkt_len) == 10
    assert int(f0.pkt_len.sum()) == 14000
    assert int(f0.ipd_us.sum()) == 2_000_000 and f0.ipd_us[0] == 0
    assert flows[1].five_tuple[4] == 17
    # reconstructed flows interleave into a well-formed stream
    stream = packet_stream(flows)
    assert (np.diff(stream["ts_us"]) >= 0).all()


def test_ustc_tfc_flow_level_adapter():
    text = ("sa,sport,da,dport,protocol,first_seen,duration_ms,"
            "pkt_count,byte_count,app\n"
            "1,1029,2,445,tcp,1000,2500,20,30000,SMB\n"
            "3,5555,4,80,tcp,1500,1200,8,1200,Neris\n")
    flows = tf.flows_from_csv_text(text, "ustc_tfc")
    classes, _ = task_meta("ustc")
    assert [classes[f.label] for f in flows] == ["smb", "neris"]
    assert flows[0].start_us == 1_000_000          # ms -> us
    assert int(flows[0].ipd_us.sum()) == 2_500_000
    assert (flows[0].pkt_len >= 40).all()          # clipped plausible IP


def test_missing_csv_column_is_a_clear_error():
    with pytest.raises(TraceFormatError, match="missing column"):
        tf.flows_from_csv_text("Src IP,Dst IP\n1,2\n", "iscx_vpn")
    with pytest.raises(TraceFormatError, match="unknown trace adapter"):
        tf.get_adapter("netflow_v5")


def test_flows_from_stream_inverts_packet_stream():
    flows = make_flows("iscx", 18, seed=12)
    oracle = packet_stream(flows)
    back = packet_stream(ti.flows_from_stream(oracle))
    for k in oracle:
        np.testing.assert_array_equal(back[k], oracle[k], err_msg=k)


def test_run_trace_source_matches_stream():
    """The streaming TraceSpec driver (double-buffered and synchronous)
    replays identically to the in-memory stream (device driver,
    deterministic model)."""
    import jax.numpy as jnp

    from repro.core.fenix import FenixConfig, FenixSystem

    class ByLen:
        num_classes = 7

        def infer(self, payload):
            return (payload[:, -1, 0] % 7).astype(jnp.int32)

    flows = make_flows("iscx", 16, seed=21)
    with tempfile.TemporaryDirectory() as tmp:
        pcap = os.path.join(tmp, "t.pcap")
        oracle = ti.synthesize_pcap(flows, pcap, limit=1024)
        v_stream = FenixSystem(FenixConfig(batch_size=256),
                               ByLen()).run_trace(oracle)["verdict"]
        sys_src = FenixSystem(FenixConfig(batch_size=256), ByLen())
        v_src = sys_src.run_trace(
            ti.TraceSpec(pcap, limit=1024))["verdict"]
        np.testing.assert_array_equal(v_src, v_stream)
        # a bare path works too (wrapped into a default TraceSpec), and
        # double-specifying the trace is rejected
        sys_p = FenixSystem(FenixConfig(batch_size=256), ByLen())
        v_path = sys_p.run_trace(
            ti.TraceSpec(pcap, limit=1024, overlap=False))["verdict"]
        np.testing.assert_array_equal(v_path, v_stream)
        with pytest.raises(ValueError, match="exactly one trace"):
            with pytest.warns(DeprecationWarning):
                sys_src.run_trace(oracle, source=pcap)
