"""Eq. 2 probability model + Appendix A fairness (property tests)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.probability import (LUTConfig, build_lut, expected_period,
                                    lut_lookup_np, mean_period_over_flows,
                                    probability, token_rate)


def test_token_rate_eq1():
    # V = min(F, B/W)
    assert token_rate(75e6, 12.5e9, 64) == 75e6
    assert token_rate(75e6, 12.5e9, 1000) == 12.5e6


@settings(max_examples=50, deadline=None)
@given(t=st.floats(1, 1e7), c=st.floats(1, 1e5), n=st.floats(1, 1e4),
       q=st.floats(0.01, 100.0), v=st.floats(1e-4, 1.0))
def test_probability_in_unit_interval(t, c, n, q, v):
    p = probability(np.asarray([t]), np.asarray([c]), n, q, v)[0]
    assert 0.0 <= p <= 1.0


def test_probability_monotone_in_t():
    """For a fixed-rate flow, waiting longer never lowers the probability."""
    n, q, v = 1000.0, 1.0, 0.075
    for qi in (0.001, 0.01, 0.1, 1.0):
        ts = np.linspace(1, 1e6, 500)
        cs = qi * ts
        ps = probability(ts, cs, n, q, v)
        assert np.all(np.diff(ps) >= -1e-9)


def test_boundaries_match_criteria():
    """P=0 below both criterion points, P=1 above both."""
    n, q, v = 1000.0, 1.0, 0.075
    qi = 0.01                      # slow flow
    lo = min(n / v, q / (qi * v))
    hi = max(n / v, q / (qi * v))
    t = np.asarray([lo * 0.5, hi * 1.5])
    c = qi * t
    p = probability(t, c, n, q, v)
    assert p[0] == 0.0 and p[1] == 1.0


def test_expected_period_formula():
    # Appendix A Eq. 6
    n, q, v = 1000.0, 1.0, 0.075
    qi = 0.05
    e = expected_period(qi, n, q, v)
    assert np.isclose(e, (qi * n + q) / (2 * qi * v))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), n_flows=st.integers(5, 200))
def test_fairness_appendix_a(seed, n_flows):
    """Rate-weighted mean period == N/V for ANY rate distribution."""
    rng = np.random.default_rng(seed)
    rates = rng.lognormal(0, 1.5, n_flows) + 1e-3
    q = rates.sum()
    v = q / 10.0
    mean = mean_period_over_flows(rates, n=n_flows, q=q, v=v)
    assert np.isclose(mean, n_flows / v, rtol=1e-9)


@pytest.mark.slow
def test_fairness_empirical_simulation():
    """Monte-carlo of the sampling process: measured E[interval] ~= N/V.

    Simulates heterogeneous Poisson-ish flows sampled by Eq.2 probabilities
    and checks the paper's fairness claim empirically, not just the algebra.
    """
    rng = np.random.default_rng(0)
    n_flows, v = 50, 0.02               # tokens per us
    rates = np.concatenate([np.full(25, 0.001), np.full(25, 0.019)])
    q = rates.sum()                     # ~0.5 pkt/us
    horizon = 4_000_000
    intervals = []
    for fi, qi in enumerate(rates):
        t_last = 0.0
        c = 0
        t = 0.0
        while t < horizon:
            t += rng.exponential(1.0 / qi)
            c += 1
            p = probability(np.asarray([t - t_last]), np.asarray([c]),
                            n_flows, q, v)[0]
            if rng.random() < p:
                intervals.append(t - t_last)
                t_last = t
                c = 0
    measured = np.mean(intervals)
    expect = n_flows / v
    assert abs(measured - expect) / expect < 0.15, (measured, expect)


def test_lut_approximates_probability():
    cfg = LUTConfig()
    n, q, v = 1000.0, 1.0, 0.075
    lut = build_lut(n, q, v, cfg)
    rng = np.random.default_rng(0)
    t = rng.integers(1, 1 << 16, 500)
    c = rng.integers(1, 32, 500)
    p_lut = lut_lookup_np(lut, t, c, cfg) / float((1 << cfg.prob_bits) - 1)
    p_true = probability(t, c, n, q, v)
    # bin-center quantization error bound
    assert np.mean(np.abs(p_lut - p_true)) < 0.08
