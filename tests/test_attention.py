"""Attention implementations vs the naive oracle (+ hypothesis sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.layers import attention, decode_attention


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(0, 1, shape), jnp.float32)


@pytest.mark.parametrize("impl", ["chunked", "bands"])
@pytest.mark.parametrize("s,hq,hkv,dk,dv,cq", [
    (64, 4, 2, 16, 16, 16),
    (96, 8, 8, 8, 8, 32),
    (128, 4, 1, 32, 16, 64),   # MQA, dv != dk
    (50, 2, 2, 16, 16, 16),    # non-multiple of chunk
])
def test_causal_impls_match_naive(impl, s, hq, hkv, dk, dv, cq):
    rng = np.random.default_rng(0)
    q = _rand(rng, 2, s, hq, dk)
    k = _rand(rng, 2, s, hkv, dk)
    v = _rand(rng, 2, s, hkv, dv)
    ref = attention(q, k, v, causal=True, impl="naive")
    out = attention(q, k, v, causal=True, impl=impl, chunk_q=cq, chunk_kv=cq)
    assert float(jnp.max(jnp.abs(ref - out))) < 1e-4


@pytest.mark.parametrize("impl", ["chunked", "bands"])
def test_window_attention(impl):
    rng = np.random.default_rng(1)
    s, win = 96, 24
    q = _rand(rng, 2, s, 4, 16)
    k = _rand(rng, 2, s, 1, 16)
    v = _rand(rng, 2, s, 1, 16)
    ref = attention(q, k, v, causal=True, impl="naive", window=win)
    out = attention(q, k, v, causal=True, impl=impl, chunk_q=16,
                    chunk_kv=16, window=win)
    assert float(jnp.max(jnp.abs(ref - out))) < 1e-4


@pytest.mark.parametrize("impl", ["chunked", "bands"])  # bands->xblocks
def test_cross_attention_non_causal(impl):
    rng = np.random.default_rng(2)
    sq, skv = 40, 72
    q = _rand(rng, 2, sq, 4, 16)
    k = _rand(rng, 2, skv, 2, 16)
    v = _rand(rng, 2, skv, 2, 16)
    ref = attention(q, k, v, causal=False, impl="naive")
    out = attention(q, k, v, causal=False, impl=impl, chunk_q=16,
                    chunk_kv=16)
    assert float(jnp.max(jnp.abs(ref - out))) < 1e-4


def test_decode_attention_matches_last_position():
    """decode at position s-1 == row s-1 of full causal attention."""
    rng = np.random.default_rng(3)
    s, hq, hkv, d = 48, 8, 2, 16
    q = _rand(rng, 2, s, hq, d)
    k = _rand(rng, 2, s, hkv, d)
    v = _rand(rng, 2, s, hkv, d)
    full = attention(q, k, v, causal=True, impl="naive")
    out = decode_attention(q[:, -1], k, v, jnp.full((2,), s))
    assert float(jnp.max(jnp.abs(full[:, -1] - out))) < 1e-4


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(s=st.integers(8, 80), hkv=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2, 4]), chunk=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 100))
def test_bands_property(s, hkv, g, chunk, seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, 1, s, hkv * g, 8)
    k = _rand(rng, 1, s, hkv, 8)
    v = _rand(rng, 1, s, hkv, 8)
    ref = attention(q, k, v, causal=True, impl="naive")
    out = attention(q, k, v, causal=True, impl="bands", chunk_q=chunk,
                    chunk_kv=chunk)
    assert float(jnp.max(jnp.abs(ref - out))) < 1e-4
