"""Minimal stand-in for ``hypothesis`` when the real package is absent.

CI installs the real hypothesis (requirements-dev.txt) and gets full
property sweeps with shrinking.  Offline/air-gapped environments fall back
to this shim (installed into ``sys.modules`` by ``conftest.py``): each
``@given`` test runs ``max_examples`` deterministic pseudo-random samples
drawn from the declared strategies — enough to keep the invariants
exercised without the dependency.

Only the API surface this repo uses is implemented: ``given``,
``settings(max_examples=, deadline=)`` and ``strategies.integers/floats/
sampled_from``.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types

_DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, sample):
        self._sample = sample


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(int(min_value), int(max_value)))


def floats(min_value, max_value, **_kw):
    return _Strategy(lambda r: r.uniform(float(min_value), float(max_value)))


def sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda r: r.choice(seq))


def given(**strats):
    for name, s in strats.items():
        if not isinstance(s, _Strategy):
            raise TypeError(f"unsupported strategy for {name!r}: {s!r}")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(0xF381)
            for _ in range(n):
                drawn = {k: s._sample(rng) for k, s in strats.items()}
                fn(*args, **kwargs, **drawn)

        # hide the strategy-supplied parameters from pytest's fixture
        # resolution (real hypothesis does the same)
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        # let conftest mark stub-backed tests so the run VISIBLY reports
        # the reduced property coverage instead of silently shrinking it
        wrapper._repro_hypothesis_stub = True
        return wrapper

    return deco


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def install() -> None:
    """Register this shim as the ``hypothesis`` package in sys.modules."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.floats = floats
    strategies.sampled_from = sampled_from
    mod.strategies = strategies
    mod.__is_repro_stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
