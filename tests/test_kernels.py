"""Pallas kernels (interpret=True) vs pure-jnp oracles: shape/dtype sweeps
+ hypothesis property sweeps, per the task requirements."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.probability import LUTConfig, build_lut
from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.int8_matmul.ops import int8_conv1d, int8_matmul
from repro.kernels.int8_matmul.ref import int8_matmul_ref
from repro.kernels.rate_gate.ops import rate_gate
from repro.kernels.rate_gate.ref import rate_gate_ref


# ---------------------------------------------------------------------------
# int8 systolic GEMM
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128), (64, 200, 130), (1, 384, 256), (300, 96, 70),
    (8, 8, 8), (129, 129, 129),
])
@pytest.mark.parametrize("shift", [None, 4, 9])
def test_int8_matmul_sweep(m, k, n, shift):
    rng = np.random.default_rng(m * 1000 + n)
    a = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    bias = jnp.asarray(rng.integers(-500, 500, (n,)), jnp.int32)
    ref = int8_matmul_ref(a, b, bias, shift)
    pal = int8_matmul(a, b, bias, shift, backend="pallas")
    assert ref.dtype == pal.dtype
    assert bool(jnp.all(ref == pal))


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 64), k=st.integers(1, 64), n=st.integers(1, 64),
       seed=st.integers(0, 1000))
def test_int8_matmul_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    assert bool(jnp.all(int8_matmul_ref(a, b)
                        == int8_matmul(a, b, backend="pallas")))


def test_int8_conv1d_matches_float():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-64, 64, (2, 9, 8)), jnp.int8)
    w = jnp.asarray(rng.integers(-64, 64, (3, 8, 16)), jnp.int8)
    got = int8_conv1d(x, w, None, None, backend="pallas")
    # float 'same' conv oracle
    xf = np.asarray(x, np.int64)
    wf = np.asarray(w, np.int64)
    pad = 1
    xp = np.pad(xf, ((0, 0), (pad, 1), (0, 0)))
    want = np.zeros((2, 9, 16), np.int64)
    for t in range(9):
        for j in range(3):
            want[:, t] += xp[:, t + j] @ wf[j]
    assert np.array_equal(np.asarray(got, np.int64), want)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,hq,hkv,d,s,ck", [
    (2, 8, 2, 64, 256, 128), (1, 4, 1, 128, 512, 256),
    (3, 16, 8, 32, 128, 64), (2, 8, 8, 64, 320, 64),
])
def test_decode_attention_sweep(b, hq, hkv, d, s, ck):
    rng = np.random.default_rng(b * 10 + s)
    q = jnp.asarray(rng.normal(0, 1, (b, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, hkv, d)), jnp.float32)
    lens = jnp.asarray(rng.integers(1, s + 1, (b,)), jnp.int32)
    ref = decode_attention_ref(q, k, v, lens)
    pal = decode_attention_pallas(q, k, v, lens, ck=ck)
    assert float(jnp.max(jnp.abs(ref - pal))) < 1e-5


def test_decode_attention_bf16():
    rng = np.random.default_rng(0)
    b, hq, hkv, d, s = 2, 4, 2, 32, 128
    q = jnp.asarray(rng.normal(0, 1, (b, hq, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (b, s, hkv, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (b, s, hkv, d)), jnp.bfloat16)
    lens = jnp.full((b,), s, jnp.int32)
    ref = decode_attention_ref(q, k, v, lens).astype(jnp.float32)
    pal = decode_attention_pallas(q, k, v, lens, ck=64).astype(jnp.float32)
    assert float(jnp.max(jnp.abs(ref - pal))) < 3e-2


# ---------------------------------------------------------------------------
# rate gate
# ---------------------------------------------------------------------------


def test_rate_gate_bit_exact():
    lcfg = LUTConfig()
    lut = jnp.asarray(build_lut(n=500, q=0.5, v=0.05, cfg=lcfg))
    rng = np.random.default_rng(0)
    n = 1000
    t = jnp.asarray(rng.integers(0, 1 << 16, n), jnp.int32)
    c = jnp.asarray(rng.integers(0, 64, n), jnp.int32)
    r16 = jnp.asarray(rng.integers(0, 1 << 16, n), jnp.int32)
    a = rate_gate(t, c, lut, rand16=r16, backend="pallas")
    b = rate_gate_ref(t, c, lut, r16, lcfg.t_shift, lcfg.c_shift)
    assert bool(jnp.all(a == b))


@settings(max_examples=10, deadline=None)
@given(n_flows=st.integers(10, 2000), v_scale=st.floats(0.01, 0.2),
       seed=st.integers(0, 100))
def test_rate_gate_rate_property(n_flows, v_scale, seed):
    """Selection frequency matches the LUT expectation (+-5%)."""
    lcfg = LUTConfig()
    lut_np = build_lut(n=float(n_flows), q=1.0, v=v_scale, cfg=lcfg)
    lut = jnp.asarray(lut_np)
    rng = np.random.default_rng(seed)
    n = 4096
    t = rng.integers(0, 1 << 16, n).astype(np.int32)
    c = rng.integers(0, 32, n).astype(np.int32)
    r16 = jnp.asarray(rng.integers(0, 1 << 16, n), jnp.int32)
    sel = rate_gate(jnp.asarray(t), jnp.asarray(c), lut, rand16=r16,
                    backend="pallas")
    ti = np.clip(t >> lcfg.t_shift, 0, lcfg.t_bins - 1)
    ci = np.clip(c >> lcfg.c_shift, 0, lcfg.c_bins - 1)
    expect = lut_np[ti, ci].sum() / float(1 << 16) / n
    got = float(np.mean(np.asarray(sel)))
    assert abs(got - expect) < 0.05
