"""Substrate: checkpointing, fault tolerance, compression, elastic plans."""

import os

import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import (CompressedState,
                                           compress_decompress,
                                           dequantize_grad, quantize_grad)
from repro.distributed.elastic import plan_remesh, scale_step_capacity
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig


def _toy_params(rng):
    return {"w": jnp.asarray(rng.normal(0, 1, (8, 8)), jnp.float32),
            "b": jnp.asarray(rng.normal(0, 1, (8,)), jnp.float32)}


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    state = {"params": _toy_params(rng), "opt": {"step": jnp.asarray(3)}}
    ckpt.save(str(tmp_path), 3, state)
    restored, meta = ckpt.restore_latest(str(tmp_path))
    assert meta["step"] == 3
    assert jnp.allclose(restored["params"]["w"], state["params"]["w"])


def test_checkpoint_keep_k(tmp_path):
    rng = np.random.default_rng(0)
    for step in range(1, 6):
        ckpt.save(str(tmp_path), step, {"p": _toy_params(rng)}, keep=2)
    assert ckpt.list_steps(str(tmp_path)) == [4, 5]


def test_checkpoint_incomplete_ignored(tmp_path):
    rng = np.random.default_rng(0)
    ckpt.save(str(tmp_path), 1, {"p": _toy_params(rng)})
    # simulate a crashed writer: directory without the COMPLETE sentinel
    os.makedirs(tmp_path / "step_00000002")
    assert ckpt.list_steps(str(tmp_path)) == [1]
    restored, meta = ckpt.restore_latest(str(tmp_path))
    assert meta["step"] == 1


def test_async_checkpointer(tmp_path):
    rng = np.random.default_rng(0)
    ac = ckpt.AsyncCheckpointer(str(tmp_path))
    ac.save(7, {"p": _toy_params(rng)})
    ac.wait()
    assert ckpt.list_steps(str(tmp_path)) == [7]


# ---------------------------------------------------------------------------
# fault tolerance (trainer-level NaN recovery)
# ---------------------------------------------------------------------------


def test_trainer_recovers_from_nan(tmp_path):
    from repro.train.trainer import Trainer, TrainerConfig

    rng = np.random.default_rng(0)
    params = _toy_params(rng)

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"] + p["b"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        # poison pill: some batches carry NaN targets (simulated bad host)
        return loss, {}

    cfg = TrainerConfig(total_steps=20, ckpt_dir=str(tmp_path),
                        ckpt_every=5, log_every=100,
                        opt=OptConfig(lr=1e-2, warmup_steps=0,
                                      total_steps=20, weight_decay=0.0))
    t = Trainer(loss_fn, params, cfg)

    def batches():
        i = 0
        while True:
            i += 1
            x = jnp.asarray(rng.normal(0, 1, (4, 8)), jnp.float32)
            y = jnp.zeros((4, 8), jnp.float32)
            if i == 8:  # one poisoned batch after the first checkpoint
                y = y * jnp.nan
            yield {"x": x, "y": y}

    t.run(batches())
    assert t.step == 20
    assert t.recoveries >= 1
    # final state is finite
    assert bool(jnp.all(jnp.isfinite(t.params["w"])))


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_quantize_grad_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, (64, 64)), jnp.float32)
    q, s = quantize_grad(g)
    err = jnp.max(jnp.abs(dequantize_grad(q, s) - g))
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_preserves_signal():
    """Sum of compressed grads converges to sum of true grads."""
    rng = np.random.default_rng(1)
    params = {"w": jnp.zeros((16, 16), jnp.float32)}
    state = CompressedState.init(params)
    true_sum = jnp.zeros((16, 16))
    comp_sum = jnp.zeros((16, 16))
    for i in range(50):
        g = {"w": jnp.asarray(rng.normal(0, 1, (16, 16)), jnp.float32)}
        cg, state = compress_decompress(g, state)
        true_sum += g["w"]
        comp_sum += cg["w"]
    # residual error is bounded by one quantization step (error feedback)
    rel = float(jnp.linalg.norm(comp_sum - true_sum)
                / jnp.linalg.norm(true_sum))
    assert rel < 0.05


def test_compressed_training_converges():
    from repro.train.trainer import Trainer, TrainerConfig

    rng = np.random.default_rng(2)
    w_true = rng.normal(0, 1, (8, 1)).astype(np.float32)
    params = {"w": jnp.zeros((8, 1), jnp.float32)}

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    def batches():
        while True:
            x = rng.normal(0, 1, (32, 8)).astype(np.float32)
            yield {"x": jnp.asarray(x), "y": jnp.asarray(x @ w_true)}

    cfg = TrainerConfig(total_steps=400, grad_compression=True,
                        log_every=10**9,
                        opt=OptConfig(lr=5e-2, warmup_steps=0,
                                      total_steps=400, weight_decay=0.0,
                                      schedule="constant"))
    t = Trainer(loss_fn, params, cfg)
    m = t.run(batches())
    assert m["loss"] < 5e-2, m["loss"]


# ---------------------------------------------------------------------------
# elastic
# ---------------------------------------------------------------------------


def test_plan_remesh_abstract():
    from repro.configs import get_config

    from conftest import abstract_mesh

    cfg = get_config("llama3.2-1b")
    mesh = abstract_mesh(("data", 4), ("model", 4))
    plan = plan_remesh(cfg, mesh)
    assert plan.n_devices == 16
    # embedding table row-sharded over model, fsdp over data
    spec = plan.pspecs["embed/table"]
    assert spec[0] == "model"


def test_scale_step_capacity():
    per, accum = scale_step_capacity(256, 128, 256)
    assert per * 128 * accum >= 256
    per, accum = scale_step_capacity(256, 512, 256)
    assert per >= 1
