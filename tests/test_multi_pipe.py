"""Multi-pipeline sharded data plane (ISSUE 2).

Invariants:

* the sharded driver forced to one pipe is *bit-identical* to the
  single-pipe device driver (states, stats, every verdict);
* slot-range partitioning preserves the flow-collision structure exactly
  (two flows collide in the P-pipe layout iff they collide in the
  single-pipe table), so routing never aliases flows across pipes;
* partitioning changes scheduling, not outcomes: with a deterministic
  per-flow model, num_pipes=1 and num_pipes=4 classify every
  collision-free flow identically (property test);
* each pipe's token bucket is bounded by its 1/P rate share;
* the occupancy-weighted merge never over- or under-serves the rings;
* shard_map and the vmap fallback agree (when >= 4 devices are up).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.data_engine import engine as de
from repro.core.data_engine.state import (EngineConfig, hash_five_tuple,
                                          init_pipes_state, init_state,
                                          local_engine_config, pipe_of_hash)
from repro.core.fenix import FenixConfig, FenixSystem
from repro.core.model_engine import delay_line as dl
from repro.core.model_engine import vector_io as vio
from repro.core.model_engine.inference import ByLenModel  # noqa: F401  (re-exported for test_engine_farm)

I32 = jnp.int32
PIPES = 4


# ByLenModel: with per-flow-constant packet lengths every
# feature window of a flow maps to the same class, so WHAT a flow
# is classified as cannot depend on which of its windows the rate
# limiter samples — the invariant the partitioning property needs.
# (shared deterministic stand-in, re-exported for test_engine_farm)


def constant_len_stream(n_pkts: int, n_flows: int, seed: int,
                        gap_us: int = 200):
    """Interleaved stream of flows with per-flow-constant pkt_len."""
    rng = np.random.default_rng(seed)
    five = {k: rng.integers(1, 2**31, n_flows).astype(np.uint32)
            for k in ("src_ip", "dst_ip")}
    five["src_port"] = rng.integers(1, 65536, n_flows).astype(np.uint32)
    five["dst_port"] = rng.integers(1, 65536, n_flows).astype(np.uint32)
    five["proto"] = rng.integers(6, 18, n_flows).astype(np.uint32)
    lens = (40 + rng.integers(0, 1400, n_flows)).astype(np.int32)
    fidx = rng.integers(0, n_flows, n_pkts).astype(np.int32)
    ts = np.sort(rng.integers(0, n_pkts * gap_us, n_pkts)).astype(np.int32)
    stream = {k: v[fidx] for k, v in five.items()}
    stream["pkt_len"] = lens[fidx]
    stream["ts_us"] = ts
    stream["flow_idx"] = fidx
    return stream, lens


def collision_free_flows(stream, lens, cfg: EngineConfig) -> np.ndarray:
    """Flow indices whose global table slot is not shared with any other
    flow (eviction-free in every num_pipes layout)."""
    fidx = stream["flow_idx"]
    first = np.unique(fidx, return_index=True)[1]
    h = np.asarray(hash_five_tuple(
        *(jnp.asarray(stream[k][first]) for k in
          ("src_ip", "dst_ip", "src_port", "dst_port", "proto"))))
    gslot = h & np.uint32(cfg.n_slots - 1)
    slot_count = np.bincount(gslot.astype(np.int64),
                             minlength=cfg.n_slots)
    return fidx[first][slot_count[gslot.astype(np.int64)] == 1]


# -- routing / config layer ---------------------------------------------------

def test_local_config_splits_rate_and_slots():
    cfg = EngineConfig()
    lcfg = local_engine_config(cfg, PIPES)
    assert lcfg.n_slots == cfg.n_slots // PIPES
    np.testing.assert_allclose(lcfg.token_rate_per_us,
                               cfg.token_rate_per_us / PIPES)
    assert local_engine_config(cfg, 1) == cfg
    with pytest.raises(ValueError):
        local_engine_config(cfg, 3)


def test_pipe_routing_preserves_collision_structure():
    cfg = EngineConfig(n_slots_log2=8)
    lcfg = local_engine_config(cfg, PIPES)
    rng = np.random.default_rng(0)
    h = rng.integers(1, 2**32, 4096, dtype=np.uint64).astype(np.uint32)
    pipe = pipe_of_hash(h, cfg, PIPES)
    assert pipe.min() >= 0 and pipe.max() < PIPES
    gslot = (h & np.uint32(cfg.n_slots - 1)).astype(np.int64)
    lslot = (h & np.uint32(lcfg.n_slots - 1)).astype(np.int64)
    # slot-range partitioning: global slot = pipe * local_n + local slot
    np.testing.assert_array_equal(gslot,
                                  pipe.astype(np.int64) * lcfg.n_slots
                                  + lslot)
    # => two hashes share (pipe, local slot) iff they share the global slot


def test_init_pipes_state_shapes_and_p1_identity():
    cfg = EngineConfig(n_slots_log2=8)
    ps = init_pipes_state(cfg, PIPES)
    lcfg = local_engine_config(cfg, PIPES)
    assert ps["hash"].shape == (PIPES, lcfg.n_slots)
    assert ps["bucket"].shape == (PIPES,)
    assert int(ps["bucket"][0]) == lcfg.bucket_cap_us
    one = init_pipes_state(cfg, 1)
    ref = init_state(cfg)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(one[k][0]),
                                      np.asarray(ref[k]), err_msg=k)


# -- merge layer --------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6), budget=st.integers(0, 300))
def test_pipe_shares_invariants(seed, budget):
    rng = np.random.default_rng(seed)
    occ = jnp.asarray(rng.integers(0, 100, PIPES), I32)
    shares = np.asarray(vio.pipe_shares(occ, jnp.asarray(budget, I32)))
    assert (shares >= 0).all()
    assert (shares <= np.asarray(occ)).all()
    assert shares.sum() == min(budget, int(np.asarray(occ).sum()))


def test_pipe_shares_single_pipe_degenerates_to_min():
    for occ, budget in ((5, 9), (9, 5), (0, 7)):
        s = vio.pipe_shares(jnp.asarray([occ], I32), jnp.asarray(budget, I32))
        assert int(s[0]) == min(occ, budget)


def test_dequeue_pipes_drains_by_share_fifo():
    cfg = vio.IOConfig(queue_len=16)
    q = vio.init_pipes_queues(cfg, 2)
    feats = jnp.zeros((6, cfg.feat_len, cfg.feat_dim), I32)
    enq = jax.vmap(lambda qp, v, s, h, f: vio.enqueue_device(
        qp, cfg, v, s, h, f))
    q = enq(q, jnp.asarray([[True] * 6, [True, True, False, False, False,
                                         False]]),
            jnp.arange(12, dtype=I32).reshape(2, 6),
            jnp.arange(1, 13, dtype=jnp.uint32).reshape(2, 6),
            jnp.stack([feats, feats]))
    occ = q["tail"] - q["head"]
    np.testing.assert_array_equal(np.asarray(occ), [6, 2])
    shares = vio.pipe_shares(occ, jnp.asarray(6, I32))
    q, s, h, f, cnt = vio.dequeue_pipes(q, cfg, shares)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(shares))
    assert int(np.asarray(cnt).sum()) == 6
    # FIFO order within each pipe's lanes
    c0 = int(cnt[0])
    np.testing.assert_array_equal(np.asarray(s)[0, :c0],
                                  np.arange(c0))


def test_delay_line_pipes_delivery_stays_in_pipe():
    cfg = EngineConfig(n_slots_log2=6)
    states = init_pipes_state(cfg, 2)
    lcfg = local_engine_config(cfg, 2)
    slots = jnp.asarray([[3], [3]], I32)
    hashes = jnp.asarray([[7], [9]], jnp.uint32)
    states["hash"] = states["hash"].at[0, 3].set(7).at[1, 3].set(9)
    dls = dl.init_pipes(8, 2)
    dls = dl.push_pipes(dls, jnp.asarray([5, 5], I32), slots, hashes,
                        jnp.asarray([[2], [4]], I32), jnp.asarray([1, 1],
                                                                  I32))
    states, dls = dl.deliver_pipes(states, dls, jnp.asarray([10, 10], I32),
                                   lcfg.n_slots)
    # same local slot, different pipes: each verdict lands only in its pipe
    assert int(states["cls"][0, 3]) == 2
    assert int(states["cls"][1, 3]) == 4


def test_process_pipes_fast_matches_per_pipe_loop():
    cfg = EngineConfig(n_slots_log2=8)
    lcfg = local_engine_config(cfg, PIPES)
    from repro.core.data_engine.state import make_packets
    rng = np.random.default_rng(3)
    per_pipe = [make_packets(rng, 64) for _ in range(PIPES)]
    batches = {k: jnp.stack([jnp.asarray(b[k]) for b in per_pipe])
               for k in per_pipe[0]}
    states = init_pipes_state(cfg, PIPES)
    out_states, outs = de.process_pipes_fast(states, batches, lcfg)
    for p in range(PIPES):
        st_p = {k: v[p] for k, v in states.items()}
        ref_st, ref_out = de.process_batch_fast(
            st_p, {k: v[p] for k, v in batches.items()}, lcfg)
        np.testing.assert_array_equal(np.asarray(out_states["hash"][p]),
                                      np.asarray(ref_st["hash"]))
        np.testing.assert_array_equal(np.asarray(outs["granted"][p]),
                                      np.asarray(ref_out["granted"]))


# -- full-system invariants ---------------------------------------------------

@pytest.fixture(scope="module")
def det_systems():
    """One system per layout, module-scoped so jits compile once."""
    model = ByLenModel()
    def mk(p):
        return FenixSystem(
            FenixConfig(batch_size=256, control_plane_every=4,
                        num_pipes=p, driver="pipes"), model)

    return mk(1), mk(PIPES)


def test_pipes_p1_bitwise_identical_to_device_driver():
    """Acceptance: the sharded path at num_pipes=1 == the current driver."""
    model = ByLenModel()
    stream, _ = constant_len_stream(2000, 40, seed=7)
    s_ref = FenixSystem(FenixConfig(batch_size=512, control_plane_every=3),
                        model)
    s_one = FenixSystem(FenixConfig(batch_size=512, control_plane_every=3,
                                    driver="pipes"), model)
    v_ref = s_ref.run_trace(stream)["verdict"]
    v_one = s_one.run_trace(stream)["verdict"]
    assert s_ref.stats == s_one.stats
    np.testing.assert_array_equal(v_ref, v_one)
    # the whole switch state agrees bit-for-bit as well
    for k in s_ref.state:
        np.testing.assert_array_equal(np.asarray(s_one.pstate[k][0]),
                                      np.asarray(s_ref.state[k]), err_msg=k)


@pytest.mark.slow
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_partitioning_preserves_per_flow_verdicts(det_systems, seed):
    """num_pipes=1 vs num_pipes=4: identical per-flow verdict multisets.

    Sharding redistributes WHEN flows are sampled, never WHAT they are
    classified as: with a deterministic per-flow model, every
    collision-free flow served in both layouts gets exactly the same
    verdict set, and (with the generous default rate) every flow is
    served in both.
    """
    s1, s4 = det_systems
    stream, lens = constant_len_stream(2048, 32, seed=seed)
    flows_ok = collision_free_flows(stream, lens, s1.cfg.engine)
    s1.reset()
    s4.reset()
    v1 = s1.run_trace(stream)["verdict"]
    v4 = s4.run_trace(stream)["verdict"]
    fidx = stream["flow_idx"]
    per_flow_1, per_flow_4 = {}, {}
    for f in flows_ok:
        per_flow_1[f] = set(v1[(fidx == f) & (v1 >= 0)].tolist())
        per_flow_4[f] = set(v4[(fidx == f) & (v4 >= 0)].tolist())
    assert per_flow_1 == per_flow_4
    served = [f for f in flows_ok if per_flow_1[f]]
    assert len(served) >= len(flows_ok) * 3 // 4
    for f in served:
        assert per_flow_1[f] == {int(lens[f]) % ByLenModel.num_classes}
    # the per-flow verdict multiset over flows — Counter of each flow's
    # final class — is identical across layouts (sharding changes WHEN a
    # flow is sampled, never WHAT it is classified as)
    from collections import Counter
    assert Counter(min(per_flow_1[f]) for f in served) == \
        Counter(min(per_flow_4[f]) for f in served)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_per_pipe_bucket_never_exceeds_rate_share(seed):
    """Token conservation per pipe: grants * cost <= share of elapsed time
    plus one bucket of burst (each pipe's bucket refills at rate/P)."""
    model = ByLenModel()
    # tight global rate so the bucket actually binds
    ecfg = EngineConfig(fpga_hz=0.05e6, link_bw_bytes=0.05e6 * 64)
    sys4 = FenixSystem(FenixConfig(engine=ecfg, batch_size=256,
                                   num_pipes=PIPES), model)
    stream, _ = constant_len_stream(2048, 32, seed=seed, gap_us=40)
    sys4.run_trace(stream)
    lcfg = sys4.lcfg
    span = int(stream["ts_us"][-1]) - int(stream["ts_us"][0])
    granted = np.asarray(sys4.pstate["granted"], np.int64)
    assert granted.sum() == sys4.stats["granted"]
    for p in range(PIPES):
        assert granted[p] * lcfg.cost_us <= \
            span + lcfg.bucket_cap_us + lcfg.cost_us, (p, granted)


@pytest.mark.skipif(jax.device_count() < PIPES,
                    reason="needs >= 4 devices for the shard_map path")
def test_shard_map_matches_vmap_fallback():
    """The mesh-sharded driver and the 1-device vmap fallback agree."""
    model = ByLenModel()
    stream, _ = constant_len_stream(2048, 32, seed=5)
    def mk():
        return FenixSystem(FenixConfig(batch_size=256, num_pipes=PIPES),
                           model)

    s_mesh = mk()
    assert s_mesh._mesh is not None
    s_vmap = mk()
    s_vmap._mesh = None          # force the fallback step
    v_mesh = s_mesh.run_trace(stream)["verdict"]
    v_vmap = s_vmap.run_trace(stream)["verdict"]
    assert s_mesh.stats == s_vmap.stats
    np.testing.assert_array_equal(v_mesh, v_vmap)
