"""Cross-driver conformance: one trace, four drivers, identical results.

The repo's strongest system invariant used to be asserted piecemeal
(host==device in test_device_path, pipes(P=1)==device in test_multi_pipe,
farm(E=1)==pipes in test_engine_farm).  This suite replays ONE synthesized
trace through every driver in a single parametrized matrix and asserts
identical verdicts, identical stats dicts (every key, including
served_per_engine and the queue-depth histograms), and identical served
counts — for the pure-JAX reference gate AND the fused Pallas admission
kernel, so the fused gate is proven bit-identical on all four driver
paths, not just the single-device one.

Degenerate configs (P=1, E=1 forced through the sharded drivers) keep the
chain exactly comparable to the host reference; the multi-pipe shapes
(P=2, 2x2 farm) can't equal the host loop but must be backend-invariant:
fused == reference per driver.
"""

import numpy as np
import pytest

from repro.core.fenix import FenixConfig, FenixSystem
from repro.core.model_engine.inference import ByLenModel
from repro.core.model_engine.vector_io import IOConfig
from repro.data.synthetic_traffic import make_flows, packet_stream

BATCH = 256
CPE = 3
LIMIT = 1800           # not a multiple of BATCH: tails covered everywhere

# every driver the system has; the degenerate sharded forms are the ones
# that must be bit-identical to the host loop
DRIVERS = {
    "host": dict(driver="host"),
    "device": dict(driver="device"),
    "pipes": dict(driver="pipes", num_pipes=1),
    "farm": dict(driver="farm", num_pipes=1, num_engines=1),
}
MULTI = {
    "pipes2": dict(driver="pipes", num_pipes=2),
    "farm2x2": dict(driver="farm", num_pipes=2, num_engines=2),
}
BACKENDS = ("ref", "pallas")


@pytest.fixture(scope="module")
def trace():
    flows = make_flows("iscx", 40, seed=7)
    return packet_stream(flows, limit=LIMIT)


_cache = {}


def _replay(trace, driver_kw, backend, key):
    """Run one driver/backend combo once per module (results are reused
    by every assertion that needs them)."""
    if key not in _cache:
        sys_ = FenixSystem(
            FenixConfig(batch_size=BATCH, control_plane_every=CPE,
                        gate_backend=backend, **driver_kw),
            ByLenModel())
        out = sys_.run_trace(dict(trace))
        _cache[key] = (np.asarray(out["verdict"]), sys_.stats,
                       sys_.host_syncs)
    return _cache[key][:2]


def _host_syncs(key):
    return _cache[key][2]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("driver", [d for d in DRIVERS if d != "host"])
def test_driver_conforms_to_host(trace, driver, backend):
    """Verdicts, stats dict, and served counts identical to the host
    reference loop — per gate backend."""
    v_ref, s_ref = _replay(trace, DRIVERS["host"], backend,
                           ("host", backend))
    v, s = _replay(trace, DRIVERS[driver], backend, (driver, backend))
    assert v.shape == v_ref.shape == (LIMIT,)
    assert (v == v_ref).all()
    assert s == s_ref
    assert s["served_per_engine"] == s_ref["served_per_engine"]
    assert s["inferences"] == s_ref["inferences"]
    # the device drivers fold the control-plane LUT rebuild into the scan:
    # identical results, zero host-driven control-plane round trips —
    # while the oracle syncs once per T_w window
    assert _host_syncs((driver, backend)) == 0
    assert _host_syncs(("host", backend)) == LIMIT // (BATCH * CPE)


@pytest.mark.parametrize("driver", sorted(DRIVERS))
def test_fused_gate_conforms_to_reference(trace, driver):
    """The fused Pallas admission kernel is bit-identical to the pure-JAX
    reference on this driver path (the tentpole acceptance criterion)."""
    v_ref, s_ref = _replay(trace, DRIVERS[driver], "ref", (driver, "ref"))
    v_pal, s_pal = _replay(trace, DRIVERS[driver], "pallas",
                           (driver, "pallas"))
    assert (v_pal == v_ref).all()
    assert s_pal == s_ref


@pytest.mark.slow
@pytest.mark.parametrize("driver", sorted(MULTI))
def test_fused_gate_conforms_on_multi_pipe_shapes(trace, driver):
    """P=2 / 2x2-farm shapes (shard_map on >=2-device hosts, vmap
    fallback otherwise): fused == reference, including per-engine
    served counts."""
    v_ref, s_ref = _replay(trace, MULTI[driver], "ref",
                           (driver, "ref"))
    v_pal, s_pal = _replay(trace, MULTI[driver], "pallas",
                           (driver, "pallas"))
    assert (v_pal == v_ref).all()
    assert s_pal == s_ref


# ---------------------------------------------------------------------------
# INT8 serving model (ISSUE 6): the trained + quantized classifier named by
# FenixConfig(model=...) replaces ByLenModel; the serving factory's
# process-wide cache guarantees every driver here serves the SAME weights.
# Smaller shapes than the ByLenModel matrix: each granted batch runs real
# GEMMs (128-padded when interpreting the Pallas kernel).
# ---------------------------------------------------------------------------

I8_BATCH = 128
I8_LIMIT = 700         # not a multiple of I8_BATCH: tails covered


@pytest.fixture(scope="module")
def trace_int8():
    flows = make_flows("iscx", 30, seed=17)
    return packet_stream(flows, limit=I8_LIMIT)


_cache_int8 = {}


def _replay_int8(trace, driver_kw, backend, key):
    if key not in _cache_int8:
        sys_ = FenixSystem(FenixConfig(
            io=IOConfig(queue_len=256), batch_size=I8_BATCH,
            control_plane_every=CPE, model="int8_cnn_tiny",
            matmul_backend=backend, **driver_kw))
        out = sys_.run_trace(dict(trace))
        _cache_int8[key] = (np.asarray(out["verdict"]), sys_.stats)
    return _cache_int8[key]


@pytest.mark.parametrize("driver", [d for d in DRIVERS if d != "host"])
def test_int8_driver_conforms_to_host(trace_int8, driver):
    """The quantized serving model produces identical verdicts and stats
    on every driver path (FenixConfig(model="int8_cnn_tiny"))."""
    v_ref, s_ref = _replay_int8(trace_int8, DRIVERS["host"], "ref",
                                ("host", "ref"))
    v, s = _replay_int8(trace_int8, DRIVERS[driver], "ref",
                        (driver, "ref"))
    assert v.shape == v_ref.shape == (I8_LIMIT,)
    assert (v == v_ref).all()
    assert s == s_ref


@pytest.mark.parametrize("driver", sorted(DRIVERS))
def test_int8_matmul_backend_conforms(trace_int8, driver):
    """matmul_backend="pallas" is bit-identical to "ref" on this driver
    path (the ISSUE-6 acceptance criterion): the interpreted Pallas GEMM
    serves the same verdicts as the jnp oracle inside the jitted scans."""
    v_ref, s_ref = _replay_int8(trace_int8, DRIVERS[driver], "ref",
                                (driver, "ref"))
    v_pal, s_pal = _replay_int8(trace_int8, DRIVERS[driver], "pallas",
                                (driver, "pallas"))
    assert (v_pal == v_ref).all()
    assert s_pal == s_ref


def test_int8_serving_actually_classifies(trace_int8):
    """The int8 matrix exercises real inference: grants, served GEMM
    batches, and DNN verdicts inside the class range."""
    v, s = _replay_int8(trace_int8, DRIVERS["host"], "ref",
                        ("host", "ref"))
    assert s["inferences"] > 0
    assert int((v >= 0).sum()) > 0
    assert int(v.max()) < 7


def test_stats_and_verdicts_sane(trace):
    """The shared trace actually exercises the pipeline: grants flow,
    inferences are served, verdicts land."""
    v, s = _replay(trace, DRIVERS["host"], "ref", ("host", "ref"))
    assert s["packets"] == LIMIT
    assert s["granted"] > 0
    assert s["inferences"] > 0
    assert (v >= -1).all()
    assert int((v >= 0).sum()) == s["classified_pkts"]