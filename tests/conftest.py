import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401  (real package, used in CI)
except ModuleNotFoundError:
    from _hypothesis_stub import install

    install()


def abstract_mesh(*axes):
    """AbstractMesh across jax versions: 0.4.3x takes ((name, size), ...),
    newer releases take (sizes, names).  ``axes`` are (name, size) pairs."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(s for _, s in axes),
                            tuple(n for n, _ in axes))
