import os
import sys
import warnings

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

_HYPOTHESIS_STUBBED = False
try:
    import hypothesis  # noqa: F401  (real package, used in CI)
except ModuleNotFoundError:
    from _hypothesis_stub import install

    install()
    _HYPOTHESIS_STUBBED = True


def pytest_collection_modifyitems(config, items):
    """When the bundled hypothesis stub is active, mark every stub-backed
    property test and warn VISIBLY: the stub runs a handful of
    deterministic samples per test (no shrinking, no database), which is
    materially less coverage than real hypothesis.  CI installs the real
    package; if this warning appears in a CI log, the job is running with
    degraded property coverage and should be treated as misconfigured.
    """
    if not _HYPOTHESIS_STUBBED:
        return
    import pytest

    stubbed = []
    for item in items:
        fn = getattr(item, "obj", None)
        if getattr(fn, "_repro_hypothesis_stub", False):
            item.add_marker(pytest.mark.hypothesis_stub)
            stubbed.append(item.nodeid)
    if stubbed:
        warnings.warn(pytest.PytestWarning(
            f"real 'hypothesis' is not installed: {len(stubbed)} property "
            "tests are running against tests/_hypothesis_stub.py with "
            "reduced example counts and no shrinking (marked "
            "'hypothesis_stub'; select with -m hypothesis_stub). Install "
            "requirements-dev.txt for full property coverage."))


def pytest_report_header(config):
    if _HYPOTHESIS_STUBBED:
        return ("hypothesis: STUB (tests/_hypothesis_stub.py) — reduced "
                "property coverage; pip install hypothesis for the real "
                "sweeps")
    return "hypothesis: real package"


def abstract_mesh(*axes):
    """AbstractMesh across jax versions: 0.4.3x takes ((name, size), ...),
    newer releases take (sizes, names).  ``axes`` are (name, size) pairs."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(s for _, s in axes),
                            tuple(n for n, _ in axes))
