"""INT8 quantization + serving-model properties (ISSUE 6):

* quantize -> dequantize round-trip error bounded by half a grid step
  (hypothesis sweep over shifts and value ranges),
* the int8 GEMM backends are bit-identical on random shapes through the
  public dispatch surface (``backend=`` override and ``set_backend``),
* a trained-and-quantized model stays within a fixed accuracy delta of
  its float parent on the synthetic fixture corpus, and the quantized
  checkpoint round-trips through ``save_quantized``/``load_quantized``.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.model_engine import serving
from repro.kernels.int8_matmul import ops
from repro.models import traffic
from repro.quant.quantize import (dequantize_array, int8_apply,
                                  quantize_array)

# ---------------------------------------------------------------------------
# quantize -> dequantize round trip
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(shift=st.integers(-4, 12), scale_exp=st.integers(-6, 6),
       seed=st.integers(0, 1000))
def test_quantize_dequantize_bounded_error(shift, scale_exp, seed):
    """|dequantize(quantize(x)) - x| <= 2^-(shift+1) — half a grid step —
    for every x inside the int8-representable range at that shift."""
    rng = np.random.default_rng(seed)
    lim = 127.0 * 2.0 ** -shift
    x = rng.uniform(-lim, lim, 64) * min(2.0 ** scale_exp, 1.0)
    err = np.abs(dequantize_array(quantize_array(x, shift), shift) - x)
    assert err.max() <= 2.0 ** -(shift + 1) + 1e-12


def test_quantize_saturates():
    """Out-of-range values clip to +-127 on the grid, never wrap."""
    x = np.asarray([-1e9, -300.0, 300.0, 1e9])
    q = quantize_array(x, 0)
    assert q.dtype == np.int8
    assert (q == np.asarray([-127, -127, 127, 127])).all()


def test_quantize_int32_grid():
    """Biases quantize onto the int32 accumulator grid losslessly for
    values far beyond int8 range."""
    x = np.asarray([-1000.5, 0.25, 12345.0])
    q = quantize_array(x, 4, np.int32)
    assert q.dtype == np.int32
    np.testing.assert_allclose(dequantize_array(q, 4), x, atol=2.0 ** -5)


# ---------------------------------------------------------------------------
# GEMM backend dispatch
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 80), k=st.integers(1, 80), n=st.integers(1, 80),
       shift=st.sampled_from([None, 3, 7]), seed=st.integers(0, 10 ** 6))
def test_int8_matmul_ref_equals_pallas(m, k, n, shift, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    bias = jnp.asarray(rng.integers(-500, 500, (n,)), jnp.int32)
    ref = ops.int8_matmul(a, b, bias, shift, backend="ref")
    pal = ops.int8_matmul(a, b, bias, shift, backend="pallas")
    assert ref.dtype == pal.dtype
    assert bool(jnp.all(ref == pal))


def test_backend_validation():
    with pytest.raises(ValueError, match="matmul_backend"):
        ops.validate_backend("mxu")
    with pytest.raises(ValueError):
        ops.int8_matmul(jnp.zeros((2, 2), jnp.int8),
                        jnp.zeros((2, 2), jnp.int8), backend="nope")
    assert ops.validate_backend("pallas") == "pallas"


def test_set_backend_is_process_default():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-127, 128, (5, 9)), jnp.int8)
    b = jnp.asarray(rng.integers(-127, 128, (9, 7)), jnp.int8)
    want = ops.int8_matmul(a, b, backend="ref")
    try:
        ops.set_backend("pallas")
        got = ops.int8_matmul(a, b)          # no per-call override
    finally:
        ops.set_backend("ref")
    assert bool(jnp.all(want == got))


# ---------------------------------------------------------------------------
# quantized model vs float parent on the fixture corpus
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def trained():
    """One trained tiny model per module: float params + quantized model
    + the eval split, off the pcap-ingested synthetic fixture corpus."""
    from repro.data.synthetic_traffic import windows_from_flows

    mcfg = serving.model_config("int8_cnn_tiny")
    flows = serving.synthetic_corpus(n_flows=160, seed=5)
    params, qp, _ = serving.train_quantized(mcfg, flows, steps=600, seed=5)
    x, y, _ = windows_from_flows(flows, seed=99)
    return mcfg, params, qp, x[:512], y[:512]


def test_quantized_accuracy_within_delta_of_float(trained):
    """Post-training INT8 quantization costs at most 5 macro-F1 points
    on the fixture corpus (the paper reports ~0.5% top-1 loss, §6)."""
    from repro.baselines.common import macro_f1

    mcfg, params, qp, x, y = trained
    pf = np.asarray(jnp.argmax(
        traffic.apply(params, mcfg, jnp.asarray(x)), -1))
    f1_float = macro_f1(y, pf, mcfg.num_classes)
    res = serving.evaluate_quantized(qp, mcfg, x, y)
    assert f1_float > 0.6          # the float model actually learned
    assert res["macro_f1"] >= f1_float - 0.05
    cm = np.asarray(res["confusion"])
    assert cm.shape == (mcfg.num_classes, mcfg.num_classes)
    assert cm.sum() == len(y)


def test_quantized_eval_backend_invariant(trained):
    """evaluate_quantized on the pallas backend returns the identical
    confusion matrix (int8_apply is bit-identical across backends)."""
    mcfg, _, qp, x, y = trained
    r_ref = serving.evaluate_quantized(qp, mcfg, x[:64], y[:64], "ref")
    r_pal = serving.evaluate_quantized(qp, mcfg, x[:64], y[:64], "pallas")
    assert r_ref["confusion"] == r_pal["confusion"]
    assert (r_ref["pred"] == r_pal["pred"]).all()


def test_quantized_checkpoint_round_trip(tmp_path, trained):
    """save_quantized -> load_quantized -> identical logits, and
    build_model(model_dir=...) serves the restored weights."""
    mcfg, _, qp, x, _ = trained
    d = str(tmp_path / "ckpt")
    serving.save_quantized(d, qp, mcfg)
    qp2, mcfg2 = serving.load_quantized(d)
    assert mcfg2 == mcfg
    xj = jnp.asarray(x[:32])
    assert bool(jnp.all(int8_apply(qp, mcfg, xj)
                        == int8_apply(qp2, mcfg2, xj)))
    m = serving.build_model("int8_cnn_tiny", model_dir=d)
    assert m.num_classes == mcfg.num_classes
    assert bool(jnp.all(m.infer(xj)
                        == jnp.argmax(int8_apply(qp, mcfg, xj), -1)))


def test_load_quantized_missing_dir(tmp_path):
    with pytest.raises(FileNotFoundError):
        serving.load_quantized(str(tmp_path / "nope"))


def test_build_model_validation():
    with pytest.raises(ValueError, match="bylen"):
        serving.build_model("bylen", matmul_backend="ref")
    with pytest.raises(ValueError, match="unknown model"):
        serving.build_model("int8_transformer")
    with pytest.raises(ValueError, match="matmul_backend"):
        serving.build_model("int8_cnn_tiny", matmul_backend="mxu")


def test_fenix_config_backend_overrides_model_object(trained):
    """FenixConfig(matmul_backend=...) rewrites the backend of an
    explicitly passed EngineModel, so one config knob flips a whole
    conformance run."""
    from repro.core.fenix import FenixConfig, FenixSystem
    from repro.core.model_engine.inference import ByLenModel, EngineModel

    mcfg, _, qp, _, _ = trained
    model = EngineModel(mcfg, qp, backend="ref")
    sys_ = FenixSystem(FenixConfig(matmul_backend="pallas"), model)
    assert sys_.model.backend == "pallas"
    assert dataclasses.replace(sys_.model, backend="ref") == model
    with pytest.raises(ValueError, match="EngineModel"):
        FenixSystem(FenixConfig(matmul_backend="pallas"), ByLenModel())
