"""End-to-end behaviour tests for the whole system (replaces placeholder).

Covers: LM serving engine (float vs int8), FENIX gate integration, the
reduced-arch training launcher path, and hypothesis ring-buffer oracle.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import api
from repro.serve.engine import ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def llama():
    cfg = get_config("llama3.2-1b", reduced=True)
    params, _ = api.init_params(cfg, seed=0)
    return cfg, params


def test_serving_engine_generates(llama):
    cfg, params = llama
    eng = ServingEngine(cfg, params, ServeConfig(max_new_tokens=6))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                                   jnp.int32)}
    out = eng.generate(batch)
    assert out["tokens"].shape == (2, 6)


def test_int8_serving_matches_float_logits(llama):
    """FENIX Model-Engine quantization on the LM: prefill logits correlate
    strongly with the float path (argmax on random init is too noisy)."""
    cfg, params = llama
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                                   jnp.int32)}
    _, lf = api.prefill(params, cfg, batch)
    qp, _ = api.quantize_for_serving(
        cfg, params, api.init_params(cfg, abstract=True)[1])
    _, lq = api.prefill(qp, cfg, batch)
    a = np.asarray(lf, np.float64).ravel()
    b = np.asarray(lq, np.float64).ravel()
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.95, corr


def test_gated_serving(llama):
    cfg, params = llama
    eng = ServingEngine(cfg, params, ServeConfig(max_new_tokens=4,
                                                 gate_backend_rate=100.0))
    rng = np.random.default_rng(2)
    # arrivals must span >> N/V (= 16/1e-4 us = 0.16s) for admissions:
    # Eq. 2 gives P=0 until a stream has waited its fair interval.
    arrivals = [{"stream": i % 3, "t_us": i * 400_000,
                 "batch": {"tokens": jnp.asarray(
                     rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)}}
                for i in range(12)]
    out = eng.serve_requests(arrivals)
    assert out["admitted"] + out["denied"] == 12
    assert out["admitted"] >= 1


@settings(max_examples=20, deadline=None)
@given(depth=st.sampled_from([4, 8]), n=st.integers(1, 40),
       seed=st.integers(0, 99))
def test_ring_buffer_oracle(depth, n, seed):
    """Ring update/assemble == collections.deque(maxlen=depth) oracle."""
    import collections
    from repro.core.data_engine import buffer_manager as bm
    from repro.core.data_engine.state import EngineConfig, init_state

    cfg = EngineConfig(n_slots_log2=4, ring_depth=depth)
    state = init_state(cfg)
    rng = np.random.default_rng(seed)
    slot = jnp.asarray(3)
    oracle = collections.deque([(0, 0)] * depth, maxlen=depth)
    for i in range(n):
        feat = (int(rng.integers(40, 1500)), int(rng.integers(0, 1000)))
        fj = jnp.asarray(feat, jnp.int32)
        payload = bm.assemble(state, cfg, slot, fj)
        want = list(oracle) + [feat]
        got = [tuple(map(int, row)) for row in np.asarray(payload)]
        assert got == want, (i, got, want)
        state = bm.push(state, cfg, slot, fj, jnp.asarray(i, jnp.int32))
        oracle.append(feat)
