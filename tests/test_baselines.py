"""Baseline schemes train and beat chance on the synthetic ISCX task."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import bos as bos_lib
from repro.baselines import n3ic as n3ic_lib
from repro.baselines.common import macro_f1
from repro.baselines.flowlens import FlowLensModel, markers
from repro.baselines.leo import LeoModel
from repro.baselines.netbeacon import NetBeaconModel
from repro.configs.fenix_models import fenix_cnn
from repro.data.synthetic_traffic import make_flows, windows_from_flows
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig, batch_iterator

K = 7
CHANCE = 1.0 / K


@pytest.fixture(scope="module")
def data():
    tr = make_flows("iscx", 250, seed=10, min_per_class=10)
    te = make_flows("iscx", 100, seed=11, min_per_class=5)
    return tr, te


def test_leo(data):
    tr, te = data
    m = LeoModel(K)
    m.fit(tr)
    r = m.predict_packets(te)
    f1 = macro_f1(r["label"], r["pred"], K)
    assert f1 > CHANCE * 1.5, f1


def test_netbeacon(data):
    tr, te = data
    m = NetBeaconModel(K)
    m.fit(tr)
    r = m.predict_packets(te)
    f1 = macro_f1(r["label"], r["pred"], K)
    assert f1 > CHANCE * 1.5, f1


def test_flowlens(data):
    tr, te = data
    x, y = markers(tr)
    xe, ye = markers(te)
    m = FlowLensModel(K, rounds=10)
    m.fit(x, y)
    f1 = macro_f1(ye, m.predict(xe), K)
    assert f1 > CHANCE * 2, f1


def test_bos(data):
    tr, te = data
    xtr, ytr, _ = windows_from_flows(tr)
    xte, yte, _ = windows_from_flows(te)
    cfg = fenix_cnn(K)
    params = bos_lib.init(cfg, 0)
    t = Trainer(lambda p, b: bos_lib.loss_fn(p, cfg, b), params,
                TrainerConfig(total_steps=120, log_every=10**9,
                              opt=OptConfig(lr=3e-3, warmup_steps=12,
                                            total_steps=120)))
    t.run(batch_iterator(xtr, ytr, 128))
    pred = np.argmax(np.asarray(
        bos_lib.apply(t.params, cfg, jnp.asarray(xte))), -1)
    f1 = macro_f1(yte, pred, K)
    assert f1 > CHANCE * 1.5, f1


def test_n3ic(data):
    tr, te = data
    x, y, _ = n3ic_lib.build_features(tr)
    xe, ye, _ = n3ic_lib.build_features(te)
    params = n3ic_lib.init(x.shape[1], K, 0)

    def batches():
        rng = np.random.default_rng(0)
        while True:
            idx = rng.integers(0, len(y), 128)
            yield {"payload": jnp.asarray(x[idx]),
                   "label": jnp.asarray(y[idx])}

    t = Trainer(lambda p, b: n3ic_lib.loss_fn(p, b), params,
                TrainerConfig(total_steps=120, log_every=10**9,
                              opt=OptConfig(lr=3e-3, warmup_steps=12,
                                            total_steps=120)))
    t.run(batches())
    pred = np.argmax(np.asarray(n3ic_lib.apply(t.params,
                                               jnp.asarray(xe))), -1)
    f1 = macro_f1(ye, pred, K)
    assert f1 > CHANCE * 1.5, f1
