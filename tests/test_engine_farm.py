"""Model-Engine farm (ISSUE 3): E FPGA engines behind one switch.

Invariants:

* the farm driver forced to one engine is *bit-identical* to the PR-2
  multi-pipeline driver (states, stats, every verdict) — at one pipe, at
  four pipes, and when ``serve_max`` binds the per-pipe dequeue;
* the occupancy-based router (``vio.engine_intake``) never assigns a lane
  beyond an engine's free ingress capacity and places every routable lane
  (engines-as-consumers waterfall);
* engine ingress FIFOs keep service order and the owning-pipe tag, so
  verdicts scatter back to the right pipe's delay line, tagged with the
  serving engine;
* engine partitioning changes scheduling, not outcomes: with a
  deterministic per-flow model, num_engines=1 and num_engines=4 classify
  every collision-free flow identically (property test);
* per-engine service stays within the per-engine budget accumulation;
* the 2-D (pipe x engine) shard_map and the nested-vmap fallback agree
  (when enough devices are up).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.fenix_models import fenix_cnn
from repro.core.data_engine.state import (EngineConfig, farm_engine_config,
                                          local_engine_config)
from repro.core.fenix import FenixConfig, FenixSystem
from repro.core.model_engine import engine_farm as farm
from repro.core.model_engine import vector_io as vio
from repro.core.model_engine.inference import CycleModel, EngineModel

from test_multi_pipe import (ByLenModel, collision_free_flows,
                             constant_len_stream)

I32 = jnp.int32
ENGINES = 4


# -- config layer -------------------------------------------------------------

def test_farm_config_scales_admission():
    cfg = EngineConfig()
    fcfg = farm_engine_config(cfg, ENGINES)
    np.testing.assert_allclose(fcfg.token_rate_per_us,
                               cfg.token_rate_per_us * ENGINES)
    assert farm_engine_config(cfg, 1) == cfg
    with pytest.raises(ValueError):
        farm_engine_config(cfg, 0)
    # pipes split the pooled rate, engines multiply it — orthogonal axes
    lcfg = local_engine_config(farm_engine_config(cfg, 2), 4)
    np.testing.assert_allclose(lcfg.token_rate_per_us,
                               cfg.token_rate_per_us * 2 / 4)


def test_farm_mesh_shape_or_fallback():
    m = farm.farm_mesh(1, 1)
    assert m is not None and m.axis_names == ("pipe", "engine")
    if jax.device_count() >= 4:
        m = farm.farm_mesh(2, 2)
        assert m is not None and m.devices.shape == (2, 2)
    assert farm.farm_mesh(64, 64) is None      # beyond any CI host


# -- router -------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6), n_lanes=st.integers(0, 400))
def test_engine_intake_never_exceeds_capacity(seed, n_lanes):
    rng = np.random.default_rng(seed)
    free = jnp.asarray(rng.integers(0, 120, ENGINES), I32)
    intake = np.asarray(vio.engine_intake(free, jnp.asarray(n_lanes, I32)))
    assert (intake >= 0).all()
    assert (intake <= np.asarray(free)).all()      # never beyond capacity
    assert intake.sum() == min(n_lanes, int(np.asarray(free).sum()))


def test_engine_intake_prefers_least_loaded():
    free = jnp.asarray([10, 90], I32)              # engine 1 nearly idle
    intake = np.asarray(vio.engine_intake(free, jnp.asarray(50, I32)))
    assert intake[1] > intake[0]
    assert intake.sum() == 50


def test_engine_queue_roundtrip_fifo_and_pipe_tags():
    cfg = vio.IOConfig(queue_len=8, feat_len=3, feat_dim=2)
    eq = vio.init_engine_queues(cfg, 2, num_pipes=2)
    e0 = {k: v[0] for k, v in eq.items()}
    feats = jnp.arange(5 * 3 * 2, dtype=I32).reshape(5, 3, 2)
    e0 = vio.enqueue_engine(e0, cfg, 2,
                            jnp.asarray([True, True, True, False, False]),
                            jnp.arange(5, dtype=I32),
                            jnp.arange(1, 6, dtype=jnp.uint32), feats,
                            jnp.asarray([0, 1, 0, 1, 1], I32))
    assert int(vio.engine_free(e0, cfg, 2)) == 2 * 8 - 3
    e0, s, h, f, p, cnt = vio.dequeue_engine(e0, cfg, 2,
                                             jnp.asarray(2, I32))
    assert int(cnt) == 2
    np.testing.assert_array_equal(np.asarray(s)[:2], [0, 1])
    np.testing.assert_array_equal(np.asarray(p)[:2], [0, 1])
    np.testing.assert_array_equal(np.asarray(f)[0], np.asarray(feats[0]))
    # remaining entry still FIFO-ordered
    e0, s, _, _, p, cnt = vio.dequeue_engine(e0, cfg, 2,
                                             jnp.asarray(9, I32))
    assert int(cnt) == 1 and int(s[0]) == 2 and int(p[0]) == 0


def test_route_ranks_maps_pipe_major():
    shares = jnp.asarray([3, 0, 2], I32)
    pipe, lane, valid = farm.route_ranks(shares, 6, jnp.asarray(2, I32),
                                         jnp.asarray(3, I32))
    # ranks 2,3,4 -> (p0,l2), (p2,l0), (p2,l1); skips the empty pipe 1
    np.testing.assert_array_equal(np.asarray(pipe)[:3], [0, 2, 2])
    np.testing.assert_array_equal(np.asarray(lane)[:3], [2, 0, 1])
    np.testing.assert_array_equal(np.asarray(valid),
                                  [True, True, True, False, False, False])


# -- full-system invariants ---------------------------------------------------

def _bit_identical(s_ref, s_farm, stream):
    v_ref = s_ref.run_trace(stream)["verdict"]
    v_farm = s_farm.run_trace(stream)["verdict"]
    assert s_ref.stats == s_farm.stats
    np.testing.assert_array_equal(v_ref, v_farm)
    for name in ("pstate", "pqueues", "pdl"):
        ref, got = getattr(s_ref, name), getattr(s_farm, name)
        for k in ref:
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(ref[k]),
                                          err_msg=f"{name}.{k}")


def test_farm_e1_bitwise_identical_to_pipes_driver():
    """Acceptance: the farm path at num_engines=1 == the PR-2 driver."""
    model = ByLenModel()
    stream, _ = constant_len_stream(2100, 40, seed=7)   # tails included
    for num_pipes in (1, 4):
        def mk(use_farm, p=num_pipes):
            return FenixSystem(
                FenixConfig(batch_size=256, control_plane_every=3,
                            num_pipes=p,
                            driver="farm" if use_farm else "pipes"), model)

        _bit_identical(mk(False), mk(True), stream)


def test_farm_e1_identity_with_serve_cap():
    """Identity also when serve_max binds the per-pipe dequeue below its
    share — the router must route the capped counts, not the shares."""
    model = ByLenModel()
    stream, _ = constant_len_stream(2048, 32, seed=3, gap_us=40)
    ecfg = EngineConfig(fpga_hz=0.05e6, link_bw_bytes=0.05e6 * 64)
    def mk(use_farm):
        return FenixSystem(
            FenixConfig(engine=ecfg, io=vio.IOConfig(serve_max=8),
                        batch_size=256, num_pipes=2,
                        driver="farm" if use_farm else "pipes"), model)

    _bit_identical(mk(False), mk(True), stream)


@pytest.fixture(scope="module")
def det_farms():
    """One system per engine count, module-scoped so jits compile once."""
    model = ByLenModel()
    def mk(e):
        return FenixSystem(
            FenixConfig(batch_size=256, control_plane_every=4,
                        num_engines=e, driver="farm"), model)

    return mk(1), mk(ENGINES)


@pytest.mark.slow
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_engine_partitioning_preserves_per_flow_verdicts(det_farms, seed):
    """num_engines=1 vs num_engines=4: identical per-flow verdict sets.

    The farm redistributes WHICH engine serves a window and WHEN, never
    WHAT the flow is classified as: with a deterministic per-flow model
    every collision-free flow served in both layouts gets exactly the
    same verdict set.
    """
    s1, s4 = det_farms
    stream, lens = constant_len_stream(2048, 32, seed=seed)
    flows_ok = collision_free_flows(stream, lens, s1.cfg.engine)
    s1.reset()
    s4.reset()
    v1 = s1.run_trace(stream)["verdict"]
    v4 = s4.run_trace(stream)["verdict"]
    assert sum(s4.stats["served_per_engine"]) == s4.stats["inferences"]
    fidx = stream["flow_idx"]
    per_flow_1, per_flow_4 = {}, {}
    for f in flows_ok:
        per_flow_1[f] = set(v1[(fidx == f) & (v1 >= 0)].tolist())
        per_flow_4[f] = set(v4[(fidx == f) & (v4 >= 0)].tolist())
    assert per_flow_1 == per_flow_4
    served = [f for f in flows_ok if per_flow_1[f]]
    assert len(served) >= len(flows_ok) * 3 // 4
    for f in served:
        assert per_flow_1[f] == {int(lens[f]) % ByLenModel.num_classes}


def test_router_capacity_and_budget_bounds():
    """Saturating run: ingress never drops (capacity-aware router) and no
    engine serves beyond its accumulated per-engine budget."""
    model = ByLenModel()
    stream, _ = constant_len_stream(4096, 64, seed=11, gap_us=10)
    ecfg = EngineConfig(fpga_hz=0.1e6, link_bw_bytes=0.1e6 * 64)
    sys_ = FenixSystem(FenixConfig(engine=ecfg, batch_size=256,
                                   num_engines=ENGINES, num_pipes=2),
                       model, n_est=0.0, q_est_pps=0.0)
    sys_.run_trace(stream)
    assert sys_.stats["dropped_eq"] == 0
    span = int(stream["ts_us"][-1]) - int(stream["ts_us"][0])
    n_rounds = -(-4096 // (2 * 256)) + 2
    # per-engine budget: floor(span * V) summed over steps, each clipped
    # to >= 1, plus the tail round's split
    bound = span * ecfg.token_rate_per_us + n_rounds + 1
    for served in sys_.stats["served_per_engine"]:
        assert served <= bound, (served, bound)
    assert sum(sys_.stats["served_per_engine"]) == sys_.stats["inferences"]
    # queue-depth histogram saw every scan round, on every engine
    for row in sys_.stats["engine_q_depth_hist"]:
        assert sum(row) >= 4096 // (2 * 256)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >= 2 devices for an engine mesh axis")
def test_shard_map_matches_vmap_on_engine_axis():
    """The 2-D mesh farm and the nested-vmap fallback agree bit-for-bit."""
    model = ByLenModel()
    stream, _ = constant_len_stream(2048, 32, seed=5)
    n_dev = jax.device_count()
    num_pipes = 2 if n_dev >= 4 else 1
    def mk():
        return FenixSystem(FenixConfig(batch_size=256,
                                       num_pipes=num_pipes,
                                       num_engines=2), model)

    s_mesh = mk()
    assert s_mesh._mesh is not None
    assert s_mesh._mesh.devices.shape == (num_pipes, 2)
    s_vmap = mk()
    s_vmap._mesh = None          # force the nested-vmap fallback
    v_mesh = s_mesh.run_trace(stream)["verdict"]
    v_vmap = s_vmap.run_trace(stream)["verdict"]
    assert s_mesh.stats == s_vmap.stats
    np.testing.assert_array_equal(v_mesh, v_vmap)


# -- inference / accounting ---------------------------------------------------

def test_infer_engines_matches_per_engine_infer():
    cfg = fenix_cnn(7)
    from repro.models import traffic
    from repro.quant.quantize import quantize_traffic
    params = traffic.init(cfg, 0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 1500, (3, 4, cfg.seq_len, 2)), I32)
    qp = quantize_traffic(params, cfg, x.reshape(12, cfg.seq_len, 2))
    model = EngineModel(cfg, qp)
    batched = np.asarray(model.infer_engines(x))
    assert batched.shape == (3, 4)
    for e in range(3):
        np.testing.assert_array_equal(batched[e],
                                      np.asarray(model.infer(x[e])))


def test_cycle_model_farm_accounting():
    cyc = CycleModel()
    cfg = fenix_cnn(7)
    np.testing.assert_allclose(cyc.farm_throughput_inf_per_s(cfg, 4),
                               4 * cyc.throughput_inf_per_s(cfg))
    l1 = cyc.farm_batch_latency_us(cfg, 256, 1)
    l2 = cyc.farm_batch_latency_us(cfg, 256, 2)
    l4 = cyc.farm_batch_latency_us(cfg, 256, 4)
    assert l1 > l2 > l4 > 0
    assert cyc.farm_batch_latency_us(cfg, 1, 1) == \
        pytest.approx(cyc.latency_us(cfg))


def test_depth_histogram_buckets():
    depths = np.asarray([[0, 1], [1, 3], [4, 200_000]])
    hist = farm.depth_histogram(depths, 2)
    assert hist[0] == [1, 1, 0, 1] + [0] * (farm.DEPTH_BUCKETS - 4)
    assert hist[1][1] == 1 and hist[1][2] == 1
    assert hist[1][farm.DEPTH_BUCKETS - 1] == 1      # saturating bucket
