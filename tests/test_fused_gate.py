"""Fused Pallas admission gate vs the pure-JAX oracle.

Property sweeps (hypothesis, stub-backed offline): for random LUTs,
bucket states, rates, and batch shapes the fused kernel must be
bit-identical to ``fused_admission_ref`` — grants AND the updated bucket
level — across every backend that runs on this host.  Invariants: the
bucket level never goes negative (or past its cap), and grants are
pointwise monotone in the token budget.

``backend="compiled"`` rows probe ``pl.pallas_call`` with
``interpret=False`` on the default jax backend and skip with an explicit
marker when this host has no non-interpret Pallas lowering (CPU jaxlibs)
— the CI lowering job surfaces that skip reason instead of silently
falling back to interpret mode.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.data_engine import engine as de
from repro.core.data_engine.state import EngineConfig, init_state, \
    make_packets
from repro.core.probability import LUTConfig, build_lut
from repro.kernels.rate_gate.ops import (GATE_BACKENDS, fused_admission,
                                         gate_lowering_supported)
from repro.kernels.rate_gate.ref import fused_admission_ref

I32 = jnp.int32
LCFG = LUTConfig()

_LOWERING = None


def _lowering():
    global _LOWERING
    if _LOWERING is None:
        _LOWERING = gate_lowering_supported()
    return _LOWERING


def _skip_unless_runnable(backend):
    """Map a test-matrix backend name onto fused_admission kwargs."""
    if backend == "reference":
        return {"backend": "ref"}
    if backend == "pallas":
        return {"backend": "pallas"}
    supported, why = _lowering()
    if not supported:
        pytest.skip("compiled gate lowering unavailable on "
                    f"{jax.default_backend()}: {why}")
    return {"backend": "pallas", "interpret": False}


def _random_case(seed, n, bucket0, t_last, cost, random_lut):
    rng = np.random.default_rng(seed)
    if random_lut:
        lut = rng.integers(0, 1 << LCFG.prob_bits,
                           (LCFG.t_bins, LCFG.c_bins)).astype(np.int32)
    else:
        lut = build_lut(n=float(rng.integers(10, 5000)),
                        q=float(rng.uniform(0.05, 4.0)),
                        v=float(rng.uniform(0.01, 0.2)), cfg=LCFG)
    t = rng.integers(0, 1 << 17, n).astype(np.int32)
    c = rng.integers(0, 128, n).astype(np.int32)
    ts = np.sort(rng.integers(t_last, t_last + 200_000, n)).astype(np.int32)
    r16 = rng.integers(0, 1 << LCFG.prob_bits, n).astype(np.int32)
    return (jnp.asarray(t), jnp.asarray(c), jnp.asarray(ts),
            jnp.asarray(lut), jnp.asarray(r16),
            jnp.asarray(bucket0, I32), jnp.asarray(t_last, I32), cost)


def _call(args, cost, cap, **kw):
    t, c, ts, lut, r16, bucket0, t_last, _ = args
    return fused_admission(t, c, ts, lut, bucket0, t_last, rand16=r16,
                           cost_us=cost, bucket_cap_us=cap,
                           t_shift=LCFG.t_shift, c_shift=LCFG.c_shift,
                           prob_bits=LCFG.prob_bits, **kw)


@pytest.mark.parametrize("backend", ["reference", "pallas",
                                     pytest.param("compiled",
                                                  marks=pytest.mark.lowering)])
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 1500),
       bucket0=st.integers(0, 600), t_last=st.integers(0, 1 << 20),
       cost=st.integers(1, 32), random_lut=st.sampled_from([True, False]))
def test_fused_matches_oracle(backend, seed, n, bucket0, t_last, cost,
                              random_lut):
    """Kernel output == pure-JAX reference, bit for bit, grants + bucket."""
    kw = _skip_unless_runnable(backend)
    args = _random_case(seed, n, bucket0, t_last, cost, random_lut)
    cap = 64 * cost
    t, c, ts, lut, r16, b0, tl, _ = args
    t_ref = jnp.where(tl == 0, ts[0], tl).astype(I32)
    burst0 = jnp.minimum(b0, cap).astype(I32)
    want_g, want_b = fused_admission_ref(t, c, ts, lut, r16, burst0, t_ref,
                                         LCFG.t_shift, LCFG.c_shift, cost,
                                         cap)
    got_g, got_b = _call(args, cost, cap, **kw)
    assert bool(jnp.all(got_g == want_g))
    assert int(got_b) == int(want_b)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 1024),
       bucket0=st.integers(0, 4000), t_last=st.integers(0, 1 << 20),
       cost=st.integers(1, 32))
def test_bucket_level_never_negative(backend, seed, n, bucket0, t_last,
                                     cost):
    """0 <= bucket' <= cap, and granted spend never exceeds credit."""
    kw = _skip_unless_runnable(backend)
    args = _random_case(seed, n, bucket0, t_last, cost, True)
    cap = 64 * cost
    granted, bucket_new = _call(args, cost, cap, **kw)
    assert 0 <= int(bucket_new) <= cap
    # numpy re-derivation: every granted packet paid within its credit
    ts, b0, tl = np.asarray(args[2]), int(args[5]), int(args[6])
    g = np.asarray(granted)
    t_ref = ts[0] if tl == 0 else tl
    credit = min(int(b0), cap) + np.maximum(ts - t_ref, 0)
    spend = np.cumsum(np.where(g, cost, 0))
    assert (spend[g] <= credit[g]).all()


@pytest.mark.parametrize("backend", ["reference", "pallas"])
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 1024),
       lo=st.integers(0, 200), extra=st.integers(1, 400),
       cost=st.integers(1, 16))
def test_grants_monotone_in_token_budget(backend, seed, n, lo, extra,
                                         cost):
    """More batch-start credit can only ADD grants, never remove one."""
    kw = _skip_unless_runnable(backend)
    args = _random_case(seed, n, lo, 7, cost, True)
    cap = 1024 * cost                      # cap far above both budgets
    g_lo, _ = _call(args, cost, cap, **kw)
    hi = list(args)
    hi[5] = jnp.asarray(lo + extra, I32)
    g_hi, _ = _call(tuple(hi), cost, cap, **kw)
    g_lo, g_hi = np.asarray(g_lo), np.asarray(g_hi)
    assert (g_hi | ~g_lo).all()


@pytest.mark.parametrize("backend", sorted(set(GATE_BACKENDS)
                                           - {"pallas_tpu"}))
def test_admit_batch_backends_bit_identical_in_engine(backend):
    """process_batch_fast end-to-end: state + outputs match backend=ref."""
    rng = np.random.default_rng(3)
    pk = make_packets(rng, 512)
    jb = {k: jnp.asarray(v) for k, v in pk.items()}
    outs = {}
    for be in ("ref", backend):
        ecfg = EngineConfig(gate_backend=be)
        st_, out = de.process_batch_fast(init_state(ecfg), dict(jb), ecfg)
        st_, out2 = de.process_batch_fast(st_, dict(jb), ecfg)
        outs[be] = (st_, out, out2)
    for (a, b) in zip(jax.tree.leaves(outs["ref"]),
                      jax.tree.leaves(outs[backend])):
        assert bool(jnp.all(a == b))


@pytest.mark.lowering
def test_fused_gate_cpu_lowering_or_explicit_skip():
    """The CI lowering job: compile interpret=False where supported.

    Hosts without a non-interpret Pallas lowering (CPU jaxlibs today)
    must skip VISIBLY with the backend's own reason — never silently run
    interpret mode and report it as a compile.
    """
    supported, why = _lowering()
    if not supported:
        assert why, "lowering probe must carry a failure reason"
        pytest.skip(f"pl.pallas_call interpret=False unsupported on "
                    f"{jax.default_backend()}: {why}")
    args = _random_case(11, 1024, 50, 0, 4, True)
    cap = 256
    want = _call(args, 4, cap, backend="ref")
    got = _call(args, 4, cap, backend="pallas", interpret=False)
    assert bool(jnp.all(got[0] == want[0]))
    assert int(got[1]) == int(want[1])
