"""Integration invariant: prefill+decode == full teacher-forcing forward.

The strongest correctness signal for every family: cached incremental
decode must reproduce the train-path logits position-for-position (fp32,
high capacity factor so MoE drops nothing)."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import api


def _full_logits(params, cfg, tokens, extras):
    mod = api._family(cfg)
    if cfg.family == "encdec":
        from repro.models import encdec
        import repro.models.layers as L
        from repro.models.param import subtree, maybe_scan
        enc_out = encdec.encode(params, cfg, extras["src_embeds"])
        x = L.embed(params, "embed", tokens).astype(cfg.activation_dtype)
        stacked = subtree(params, "dec/")

        def body(x, p_l):
            return encdec._dec_layer(p_l, cfg, x, enc_out=enc_out,
                                     mode="train")[0], None

        x, _ = maybe_scan(body, x, stacked, cfg.scan_layers)
        x = L.rmsnorm(params, "ln_f", x, cfg.norm_eps)
        return L.logits_head(params, x,
                             None if cfg.tie_embeddings else "head", "embed")
    if cfg.family == "vlm":
        return mod.forward_train(params, cfg, tokens,
                                 extras["image_embeds"])[0]
    return mod.forward_train(params, cfg, tokens)[0]


# PR-gate tier keeps one arch per family class (dense decoder, SSM, MoE,
# enc-dec); the remaining archs run in the scheduled slow tier
_FAST_ARCHS = {"llama3.2-1b", "mamba2-370m", "qwen2-moe-a2.7b",
               "seamless-m4t-medium"}
# a renamed arch must fail collection, not silently demote its family
# to the weekly tier
assert _FAST_ARCHS <= set(list_archs()), \
    f"stale _FAST_ARCHS entries: {_FAST_ARCHS - set(list_archs())}"


@pytest.mark.parametrize("arch", [
    a if a in _FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow)
    for a in sorted(list_archs())])
def test_prefill_decode_match_forward(arch):
    cfg = get_config(arch, reduced=True)
    cfg = dataclasses.replace(cfg, activation_dtype="float32",
                              param_dtype="float32")
    if cfg.moe.num_experts:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    rng = np.random.default_rng(0)
    params, _ = api.init_params(cfg, seed=0)
    B, S, SRC = 2, 48, 40
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    extras = {}
    if cfg.family == "encdec":
        extras["src_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, SRC, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        extras["image_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.num_image_tokens, cfg.d_model)),
            jnp.float32)
    full = _full_logits(params, cfg, tokens, extras)
    pre_batch = dict(extras)
    pre_batch["tokens"] = tokens[:, :S - 1]
    cache, pre_logits = api.prefill(params, cfg, pre_batch)
    cache = api.grow_cache(cfg, cache, B, S - 1, S + 4, src_len=SRC)
    cache2, dec_logits = api.decode_step(params, cfg, cache, tokens[:, S - 1])
    assert float(jnp.max(jnp.abs(pre_logits - full[:, S - 2]))) < 2e-3
    assert float(jnp.max(jnp.abs(dec_logits - full[:, S - 1]))) < 2e-3


def test_two_decode_steps_chain():
    """Decode twice; position S and S+1 logits both match the forward."""
    cfg = get_config("llama3.2-1b", reduced=True)
    cfg = dataclasses.replace(cfg, activation_dtype="float32",
                              param_dtype="float32")
    rng = np.random.default_rng(2)
    params, _ = api.init_params(cfg, seed=0)
    B, S = 2, 24
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full = api._family(cfg).forward_train(params, cfg, tokens)[0]
    cache, _ = api.prefill(params, cfg, {"tokens": tokens[:, :S - 2]})
    cache = api.grow_cache(cfg, cache, B, S - 2, S + 2)
    cache, lg1 = api.decode_step(params, cfg, cache, tokens[:, S - 2])
    cache, lg2 = api.decode_step(params, cfg, cache, tokens[:, S - 1])
    assert float(jnp.max(jnp.abs(lg1 - full[:, S - 2]))) < 2e-3
    assert float(jnp.max(jnp.abs(lg2 - full[:, S - 1]))) < 2e-3
