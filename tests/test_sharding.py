"""Sharding rules, divisibility fallbacks, pspec generation, pipe meshes.

The rule-logic tests run on ``AbstractMesh``es whose axis sizes derive from
the *live* device count (scaled up to a floor of 16 and clamped so the
divisibility assertions stay meaningful) — no hard-coded mesh, so the same
file passes under the 1-device and the 4-virtual-device
(``--xla_force_host_platform_device_count=4``) CI entries.  The pipe-mesh
tests build a real ``Mesh`` over whatever devices are actually up and prove
the sharded Data-Engine path against its vmap reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.models import api
from repro.models.param import sharding_ctx, spec_for, tree_pspecs

from conftest import abstract_mesh


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 1).bit_length()


# abstract axis size from the live device count: >= 16 so non-divisible
# shapes exist, <= 64 so the model shapes below still shard
N_DEV = jax.device_count()
AXIS = min(max(16, _next_pow2(N_DEV)), 64)
MESH1 = abstract_mesh(("data", AXIS), ("model", AXIS))
MESH2 = abstract_mesh(("pod", 2), ("data", AXIS), ("model", AXIS))


def test_spec_divisibility_fallback():
    with sharding_ctx(MESH1):
        # AXIS*5/2 heads leave a remainder of AXIS/2 -> replicated
        bad = AXIS * 5 // 2
        spec = spec_for((5120, bad, 128), ("embed", "heads", "head_dim"))
        assert spec == P("data", None, None)
        # divisible heads shard
        spec = spec_for((5120, 2 * AXIS, 128), ("embed", "heads",
                                                "head_dim"))
        assert spec == P("data", "model", None)


def test_spec_axis_used_once():
    with sharding_ctx(MESH2):
        # batch takes (pod,data); a second 'embed'->(pod,data) must drop
        spec = spec_for((8 * 2 * AXIS, 4096, 5120), ("batch", "seq",
                                                     "embed"))
        assert spec == P(("pod", "data"), None, None)


def test_pod_axis_filtered_on_single_pod():
    with sharding_ctx(MESH1):
        spec = spec_for((16 * AXIS, 4096), ("batch", "seq"))
        assert spec == P("data", None)


@pytest.mark.parametrize("arch", sorted(list_archs()))
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["single", "multi"])
def test_all_params_get_specs(arch, mesh):
    cfg = get_config(arch)
    params, axes = api.init_params(cfg, abstract=True)
    with sharding_ctx(mesh):
        specs = tree_pspecs(params, axes, mesh)
    assert set(specs) == set(params)
    # every spec is consistent with its array rank
    for k, spec in specs.items():
        assert len(spec) <= len(params[k].shape), k
    # at least half of the big tensors are actually sharded
    big = [k for k, v in params.items()
           if len(v.shape) >= 2 and min(v.shape) >= 64]
    sharded = [k for k in big
               if any(s is not None for s in specs[k])]
    assert len(sharded) >= len(big) // 2, (arch, len(sharded), len(big))


@pytest.mark.parametrize("arch", sorted(list_archs()))
def test_cache_specs_shardable(arch):
    cfg = get_config(arch)
    specs = api.cache_specs(cfg, 128, 32768)
    with sharding_ctx(MESH1):
        for k, (shape, dt, ax) in specs.items():
            spec = spec_for(shape, ax)
            assert len(spec) <= len(shape)


def test_quantized_params_keep_specs():
    cfg = get_config("llama3.2-1b")
    params, axes = api.init_params(cfg, abstract=True)
    qp, qa = api.quantize_for_serving(cfg, params, axes)
    n_scales = sum(1 for k in qp if k.endswith("_scale"))
    assert n_scales > 0
    with sharding_ctx(MESH1):
        specs = tree_pspecs(qp, qa, MESH1)
    assert set(specs) == set(qp)


# -- live pipe mesh (real devices, not abstract) ------------------------------

def test_pipe_mesh_from_live_devices():
    """The data-plane mesh is built from whatever devices are up."""
    from repro.core.fenix import pipe_mesh

    mesh = pipe_mesh(N_DEV)
    assert mesh is not None and mesh.shape == {"pipe": N_DEV}
    # more pipes than devices -> vmap fallback, not an error
    assert pipe_mesh(2 * _next_pow2(N_DEV)) is None


def test_pipe_sharded_engine_matches_vmap():
    """shard_map over the live mesh == process_pipes_fast (vmap) on the
    per-pipe Data Engine — whatever the CI device count is."""
    try:
        from jax import shard_map  # type: ignore[attr-defined]
    except ImportError:
        from jax.experimental.shard_map import shard_map
    from repro.core.data_engine import engine as de
    from repro.core.data_engine.state import (EngineConfig,
                                              init_pipes_state,
                                              local_engine_config,
                                              make_packets)
    from repro.core.fenix import pipe_mesh

    # largest power of two <= the live device count, so the mesh always fits
    # (3-GPU boxes, odd virtual-device counts, ...)
    num_pipes = 1 << (N_DEV.bit_length() - 1)
    mesh = pipe_mesh(num_pipes)
    assert mesh is not None
    cfg = EngineConfig(n_slots_log2=8)
    lcfg = local_engine_config(cfg, num_pipes)
    rng = np.random.default_rng(0)
    per_pipe = [make_packets(rng, 128) for _ in range(num_pipes)]
    batches = {k: jnp.stack([jnp.asarray(b[k]) for b in per_pipe])
               for k in per_pipe[0]}
    states = init_pipes_state(cfg, num_pipes)

    def shard_body(st, pk):
        st, out = de.process_batch_fast(
            *jax.tree.map(lambda x: x[0], (st, pk)), lcfg)
        return jax.tree.map(lambda x: jnp.asarray(x)[None], (st, out))

    sharded = jax.jit(shard_map(shard_body, mesh=mesh, in_specs=P("pipe"),
                                out_specs=P("pipe")))
    st_s, out_s = sharded(states, batches)
    st_v, out_v = de.process_pipes_fast(states, batches, lcfg)
    for k in st_v:
        np.testing.assert_array_equal(np.asarray(st_s[k]),
                                      np.asarray(st_v[k]), err_msg=k)
    np.testing.assert_array_equal(np.asarray(out_s["granted"]),
                                  np.asarray(out_v["granted"]))
