"""Sharding rules, divisibility fallbacks, pspec generation (AbstractMesh —
no devices needed; the compile-level proof is launch/dryrun.py)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.models import api
from repro.models.param import (DEFAULT_RULES, sharding_ctx, spec_for,
                                tree_pspecs)

from conftest import abstract_mesh

MESH1 = abstract_mesh(("data", 16), ("model", 16))
MESH2 = abstract_mesh(("pod", 2), ("data", 16), ("model", 16))


def test_spec_divisibility_fallback():
    with sharding_ctx(MESH1):
        # 40 heads not divisible by model=16 -> replicated
        spec = spec_for((5120, 40, 128), ("embed", "heads", "head_dim"))
        assert spec == P("data", None, None)
        # divisible heads shard
        spec = spec_for((5120, 32, 128), ("embed", "heads", "head_dim"))
        assert spec == P("data", "model", None)


def test_spec_axis_used_once():
    with sharding_ctx(MESH2):
        # batch takes (pod,data); a second 'embed'->(pod,data) must drop
        spec = spec_for((256, 4096, 5120), ("batch", "seq", "embed"))
        assert spec == P(("pod", "data"), None, None)


def test_pod_axis_filtered_on_single_pod():
    with sharding_ctx(MESH1):
        spec = spec_for((256, 4096), ("batch", "seq"))
        assert spec == P("data", None)


@pytest.mark.parametrize("arch", sorted(list_archs()))
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["single", "multi"])
def test_all_params_get_specs(arch, mesh):
    cfg = get_config(arch)
    params, axes = api.init_params(cfg, abstract=True)
    with sharding_ctx(mesh):
        specs = tree_pspecs(params, axes, mesh)
    assert set(specs) == set(params)
    # every spec is consistent with its array rank
    for k, spec in specs.items():
        assert len(spec) <= len(params[k].shape), k
    # at least half of the big tensors are actually sharded
    big = [k for k, v in params.items()
           if len(v.shape) >= 2 and min(v.shape) >= 64]
    sharded = [k for k in big
               if any(s is not None for s in specs[k])]
    assert len(sharded) >= len(big) // 2, (arch, len(sharded), len(big))


@pytest.mark.parametrize("arch", sorted(list_archs()))
def test_cache_specs_shardable(arch):
    cfg = get_config(arch)
    specs = api.cache_specs(cfg, 128, 32768)
    with sharding_ctx(MESH1):
        for k, (shape, dt, ax) in specs.items():
            spec = spec_for(shape, ax)
            assert len(spec) <= len(shape)


def test_quantized_params_keep_specs():
    cfg = get_config("llama3.2-1b")
    params, axes = api.init_params(cfg, abstract=True)
    qp, qa = api.quantize_for_serving(cfg, params, axes)
    n_scales = sum(1 for k in qp if k.endswith("_scale"))
    assert n_scales > 0
    with sharding_ctx(MESH1):
        specs = tree_pspecs(qp, qa, MESH1)
    assert set(specs) == set(qp)
