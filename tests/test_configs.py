"""Registry + analytic parameter counts for the assigned architectures."""

import pytest

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.models.api import analytic_param_count, model_flops

EXPECTED_ARCHS = {
    "deepseek-v2-236b", "qwen2-moe-a2.7b", "llama3.2-1b", "qwen2.5-14b",
    "qwen3-4b", "gemma-7b", "mamba2-370m", "recurrentgemma-9b",
    "seamless-m4t-medium", "llama-3.2-vision-11b",
}

# loose published total-parameter envelopes (matmul params, see api.py)
PARAM_ENVELOPES = {
    "deepseek-v2-236b": (180e9, 260e9),
    "qwen2-moe-a2.7b": (8e9, 16e9),       # 14.3B total / 2.7B active
    "llama3.2-1b": (0.8e9, 1.6e9),
    "qwen2.5-14b": (11e9, 16e9),
    "qwen3-4b": (3e9, 5e9),
    "gemma-7b": (7e9, 10e9),
    "mamba2-370m": (0.25e9, 0.5e9),
    "recurrentgemma-9b": (7e9, 11e9),
    "seamless-m4t-medium": (0.3e9, 1.2e9),
    "llama-3.2-vision-11b": (8e9, 12e9),
}


def test_all_archs_registered():
    assert set(list_archs()) == EXPECTED_ARCHS


@pytest.mark.parametrize("arch", sorted(EXPECTED_ARCHS))
def test_param_counts(arch):
    cfg = get_config(arch)
    n = analytic_param_count(cfg)
    lo, hi = PARAM_ENVELOPES[arch]
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_active_params_moe():
    ds = get_config("deepseek-v2-236b")
    total = analytic_param_count(ds)
    active = analytic_param_count(ds, active_only=True)
    # deepseek-v2: 236B total / 21B active
    assert active < total / 5
    assert 12e9 <= active <= 30e9


def test_long_context_applicability():
    for arch in EXPECTED_ARCHS:
        cfg = get_config(arch)
        ok, reason = shape_applicable(cfg, SHAPES["long_500k"])
        expect = arch in ("mamba2-370m", "recurrentgemma-9b")
        assert ok == expect, (arch, reason)


@pytest.mark.parametrize("arch", sorted(EXPECTED_ARCHS))
def test_model_flops_positive(arch):
    cfg = get_config(arch)
    for shape in SHAPES.values():
        if shape_applicable(cfg, shape)[0]:
            assert model_flops(cfg, shape) > 0


def test_reduced_configs_small():
    for arch in EXPECTED_ARCHS:
        cfg = get_config(arch, reduced=True)
        assert analytic_param_count(cfg) < 5e6, arch
