"""FENIX system behaviour: quantization fidelity, Vector I/O ordering,
end-to-end co-simulation accuracy, serve gate fairness."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.fenix_models import fenix_cnn, fenix_rnn
from repro.core.gate import GateConfig, ServeGate
from repro.core.model_engine import vector_io as vio
from repro.data.synthetic_traffic import (make_flows, packet_stream,
                                          windows_from_flows)
from repro.models import traffic
from repro.quant.quantize import int8_apply, quantize_traffic
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig, batch_iterator


@pytest.fixture(scope="module")
def trained_cnn():
    flows = make_flows("iscx", 150, seed=5)
    x, y, f = windows_from_flows(flows)
    cfg = fenix_cnn(7)
    params = traffic.init(cfg, 0)
    t = Trainer(lambda p, b: traffic.loss_fn(p, cfg, b), params,
                TrainerConfig(total_steps=150, log_every=10**9,
                              opt=OptConfig(lr=3e-3, warmup_steps=15,
                                            total_steps=150)))
    t.run(batch_iterator(x, y, 256))
    return cfg, t.params, (flows, x, y, f)


def test_int8_quantization_fidelity(trained_cnn):
    """Paper §6: quantization gives 'only negligible degradation'."""
    cfg, params, (flows, x, y, f) = trained_cnn
    qp = quantize_traffic(params, cfg, jnp.asarray(x[:256]))
    fl = np.argmax(np.asarray(traffic.apply(params, cfg,
                                            jnp.asarray(x[:800]))), -1)
    q8 = np.argmax(np.asarray(int8_apply(qp, cfg, jnp.asarray(x[:800]))), -1)
    agree = float(np.mean(fl == q8))
    assert agree > 0.95, agree


def test_rnn_quantization_runs():
    flows = make_flows("iscx", 60, seed=6)
    x, y, f = windows_from_flows(flows)
    cfg = fenix_rnn(7)
    params = traffic.init(cfg, 0)
    qp = quantize_traffic(params, cfg, jnp.asarray(x[:128]))
    out = int8_apply(qp, cfg, jnp.asarray(x[:64]))
    assert out.shape == (64, 7)


def test_vector_io_fifo_ordering():
    """§5.1 invariant: results pair with ids in FIFO order."""
    cfg = vio.IOConfig(queue_len=16)
    q = vio.init_queues(cfg)
    slots = np.arange(10, dtype=np.int32)
    hashes = (slots + 100).astype(np.uint32)
    feats = np.zeros((10, cfg.feat_len, cfg.feat_dim), np.int32)
    feats[:, 0, 0] = slots
    q = vio.enqueue_batch(q, cfg, slots, hashes, feats)
    q, s1, h1, f1 = vio.dequeue_batch(q, cfg, 4)
    assert list(s1) == [0, 1, 2, 3]
    q, s2, h2, f2 = vio.dequeue_batch(q, cfg, 100)
    assert list(s2) == [4, 5, 6, 7, 8, 9]
    assert vio.occupancy(q) == 0


def test_vector_io_overflow_drops():
    cfg = vio.IOConfig(queue_len=4)
    q = vio.init_queues(cfg)
    slots = np.arange(8, dtype=np.int32)
    q = vio.enqueue_batch(q, cfg, slots, slots.astype(np.uint32),
                          np.zeros((8, cfg.feat_len, cfg.feat_dim),
                                   np.int32))
    assert int(q["dropped"]) == 4
    assert vio.occupancy(q) == 4


def test_end_to_end_cosim_accuracy(trained_cnn):
    """Packets -> switch -> rate limiter -> INT8 DNN -> flow verdicts."""
    from repro.core.fenix import FenixConfig, FenixSystem
    from repro.core.model_engine.inference import EngineModel
    from repro.core.data_engine.decision_tree import fit_tree, tree_arrays

    cfg, params, (flows, x, y, f) = trained_cnn
    qp = quantize_traffic(params, cfg, jnp.asarray(x[:256]))
    model = EngineModel(cfg, qp)
    tree = tree_arrays(fit_tree(x[:, -1, :], y, depth=4, num_classes=7))
    stream = packet_stream(flows, limit=6000)
    oracle = [np.stack([fl.pkt_len, fl.ipd_us], -1).astype(np.int32)
              for fl in flows]
    sys_ = FenixSystem(FenixConfig(), model, tree=tree,
                       oracle_windows=oracle)
    out = sys_.run_trace(stream)
    v, lab = out["verdict"], stream["label"]
    mask = v >= 0
    assert mask.mean() > 0.9
    acc = float(np.mean(v[mask] == lab[mask]))
    assert acc > 0.75, acc
    assert sys_.stats["granted"] > 0
    assert sys_.stats["inferences"] > 0


def test_serve_gate_fairness():
    """Fast streams must not starve slow streams (Appendix A transferred)."""
    cfg = GateConfig(backend_rate=1000.0)
    gate = ServeGate(cfg, seed=0)
    rng = np.random.default_rng(0)
    admitted = {0: 0, 1: 0}
    t = 0
    # stream 0: 10x the request rate of stream 1
    for i in range(30000):
        t += int(rng.exponential(100))
        sid = 0 if rng.random() < 10 / 11 else 1
        if gate.offer(sid, t):
            admitted[sid] += 1
        if i % 5000 == 4999:
            gate.refresh()
    assert admitted[0] > 0 and admitted[1] > 0
    ratio = admitted[0] / max(admitted[1], 1)
    # rate-proportional would be 10:1; the gate pulls toward parity (<5:1)
    assert ratio < 6.0, ratio
