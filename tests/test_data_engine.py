"""Data Engine invariants: flow tracking, ring semantics, token bucket.

Includes a python-oracle simulation of the switch pipeline and hypothesis
property tests of the system invariants (bucket bounds, grant rate <= V,
ring = last-8 window)."""

import collections

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.data_engine import engine as de
from repro.core.data_engine.state import (EngineConfig, hash_five_tuple,
                                          init_state)

CFG = EngineConfig(n_slots_log2=8, ring_depth=8)


def _stream(rng, n_pkts, n_flows, rate_us=50):
    flows = [{
        "src_ip": np.uint32(rng.integers(1, 2**31)),
        "dst_ip": np.uint32(rng.integers(1, 2**31)),
        "src_port": np.uint32(rng.integers(1, 65535)),
        "dst_port": np.uint32(rng.integers(1, 65535)),
        "proto": np.uint32(6),
    } for _ in range(n_flows)]
    pk = {k: np.empty(n_pkts, np.uint32) for k in flows[0]}
    pk["ts_us"] = np.sort(rng.integers(0, n_pkts * rate_us, n_pkts)
                          ).astype(np.int32)
    pk["pkt_len"] = rng.integers(40, 1500, n_pkts).astype(np.int32)
    fidx = rng.integers(0, n_flows, n_pkts)
    for k in flows[0]:
        pk[k] = np.asarray([flows[i][k] for i in fidx], np.uint32)
    return pk, fidx


def test_flow_tracker_new_flow_counting():
    rng = np.random.default_rng(0)
    pk, fidx = _stream(rng, 500, 37)
    state = init_state(CFG)
    state, out = de.process_batch(state, {k: jnp.asarray(v)
                                          for k, v in pk.items()}, CFG)
    # new-flow count == distinct slots touched (modulo collisions)
    n_new = int(np.sum(np.asarray(out["is_new"])))
    slots = set(np.asarray(out["slot"]).tolist())
    assert n_new >= len(slots)          # collisions re-init entries
    assert int(state["win_pkt_cnt"]) == 500


def test_ring_holds_last_depth_features():
    """Ring contents == last `depth` packet features of the flow (oracle)."""
    rng = np.random.default_rng(1)
    pk, fidx = _stream(rng, 400, 3)     # few flows => deep rings
    state = init_state(CFG)
    state, out = de.process_batch(state, {k: jnp.asarray(v)
                                          for k, v in pk.items()}, CFG)
    # python oracle: last 8 (len, ipd) per flow — only when no collisions
    slots = np.asarray(out["slot"])
    ring = np.asarray(state["ring"])
    buff_idx = np.asarray(state["buff_idx"])
    hist = collections.defaultdict(list)
    last_ts = {}
    for i in range(len(fidx)):
        fi = int(fidx[i])
        ipd = pk["ts_us"][i] - last_ts.get(fi, pk["ts_us"][i])
        hist[fi].append((int(pk["pkt_len"][i]), max(int(ipd), 0)))
        last_ts[fi] = pk["ts_us"][i]
    for fi in set(fidx.tolist()):
        slot = int(slots[fidx == fi][0])
        want = hist[fi][-CFG.ring_depth:]
        idx = int(buff_idx[slot])
        order = [(idx + j) % CFG.ring_depth for j in range(CFG.ring_depth)]
        got = [tuple(ring[slot, o]) for o in order][-len(want):]
        assert [tuple(map(int, g)) for g in got] == want, fi


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(50, 300))
def test_token_bucket_invariants(seed, n):
    rng = np.random.default_rng(seed)
    pk, _ = _stream(rng, n, 11)
    state = init_state(CFG)
    state, out = de.process_batch(state, {k: jnp.asarray(v)
                                          for k, v in pk.items()}, CFG)
    bucket = int(state["bucket"])
    assert 0 <= bucket <= CFG.bucket_cap_us
    # grants bounded by refill + initial capacity
    span = int(pk["ts_us"][-1]) - int(pk["ts_us"][0])
    max_grants = (span + CFG.bucket_cap_us) // CFG.cost_us + 1
    assert int(state["granted"]) <= max_grants


def test_fast_mode_matches_scan_grant_rate():
    """Vectorized admission approximates the exact scan within 20% grants."""
    rng = np.random.default_rng(3)
    pk, _ = _stream(rng, 1024, 64, rate_us=200)
    jb = {k: jnp.asarray(v) for k, v in pk.items()}
    s1, o1 = de.process_batch(init_state(CFG), dict(jb), CFG)
    s2, o2 = de.process_batch_fast(init_state(CFG), dict(jb), CFG)
    g1 = int(np.sum(np.asarray(o1["granted"])))
    g2 = int(np.sum(np.asarray(o2["granted"])))
    assert g1 > 0 and g2 > 0
    assert abs(g1 - g2) <= max(0.25 * g1, 8), (g1, g2)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(1, 500),
       n_slots=st.sampled_from([4, 64, 256]))
def test_running_count_segment_matches_dense(seed, n, n_slots):
    """O(n log n) sort/segment backlog count == the O(n^2) reference."""
    rng = np.random.default_rng(seed)
    slot = jnp.asarray(rng.integers(0, n_slots, n), jnp.int32)
    seg = np.asarray(de._running_count(slot, n))
    dense = np.asarray(de._running_count_dense(slot, n))
    assert (seg == dense).all()


def test_fast_path_segment_equals_dense_outputs():
    """Whole fast path is bit-identical under either backlog counter."""
    rng = np.random.default_rng(7)
    pk, _ = _stream(rng, 1024, 64, rate_us=200)
    jb = {k: jnp.asarray(v) for k, v in pk.items()}
    cfg_d = EngineConfig(n_slots_log2=8, dense_backlog=True)
    s1, o1 = de.process_batch_fast(init_state(CFG), dict(jb), CFG)
    s2, o2 = de.process_batch_fast(init_state(cfg_d), dict(jb), cfg_d)
    for k in o1:
        assert (np.asarray(o1[k]) == np.asarray(o2[k])).all(), k
    for k in ("bucket", "granted", "flow_cnt", "t_last"):
        assert int(s1[k]) == int(s2[k]), k


def test_gate_backend_pallas_matches_ref():
    """rate_gate Pallas kernel (interpret fallback) == inline jnp gate."""
    rng = np.random.default_rng(8)
    pk, _ = _stream(rng, 512, 32, rate_us=150)
    jb = {k: jnp.asarray(v) for k, v in pk.items()}
    cfg_p = EngineConfig(n_slots_log2=8, gate_backend="pallas")
    s1, o1 = de.process_batch_fast(init_state(CFG), dict(jb), CFG)
    s2, o2 = de.process_batch_fast(init_state(cfg_p), dict(jb), cfg_p)
    assert (np.asarray(o1["granted"]) == np.asarray(o2["granted"])).all()
    assert int(s1["granted"]) == int(s2["granted"])


def test_fast_mode_exact_on_spread_timestamps():
    """Fast admission == exact scan when the approximation is lossless.

    One packet per flow (no within-batch ring collapse), saturated LUT (no
    probabilistic divergence from RNG draw order) and timestamps spread by
    >= cost_us (the token bucket never binds): grants, payloads, is_new and
    verdicts must match the sequential switch pipeline exactly.
    """
    rng = np.random.default_rng(9)
    cand, _ = _stream(rng, 600, 600)
    cand["src_ip"] = np.arange(1, 601, dtype=np.uint32)  # distinct 5-tuples
    h = np.asarray(hash_five_tuple(*(jnp.asarray(cand[k])
                                     for k in ("src_ip", "dst_ip",
                                               "src_port", "dst_port",
                                               "proto"))))
    slots = h & (CFG.n_slots - 1)
    _, first = np.unique(slots, return_index=True)   # unique slot per pkt
    keep = np.sort(first)[:128]
    n = len(keep)
    pk = {k: v[keep] for k, v in cand.items()}
    pk["ts_us"] = (np.arange(n, dtype=np.int32) * 2 * CFG.cost_us)
    jb = {k: jnp.asarray(v) for k, v in pk.items()}
    s_scan = init_state(CFG)
    s_fast = init_state(CFG)
    full = jnp.full_like(s_scan["lut"], 1 << CFG.lut.prob_bits)
    s_scan["lut"] = full
    s_fast["lut"] = full
    s1, o1 = de.process_batch(s_scan, dict(jb), CFG)
    s2, o2 = de.process_batch_fast(s_fast, dict(jb), CFG)
    for k in ("granted", "slot", "hash", "payload", "verdict", "is_new"):
        assert (np.asarray(o1[k]) == np.asarray(o2[k])).all(), k
    assert int(s1["granted"]) == int(s2["granted"]) == n


def test_classification_result_application():
    from repro.core.data_engine import flow_tracker as ft
    state = init_state(CFG)
    h = hash_five_tuple(*(jnp.asarray(x, jnp.uint32)
                          for x in (1, 2, 3, 4, 6)))
    slot = (h & jnp.uint32(CFG.n_slots - 1)).astype(jnp.int32)
    state["hash"] = state["hash"].at[slot].set(h)
    state = ft.apply_inference_result(state, slot, jnp.asarray(5), h)
    assert int(state["cls"][slot]) == 5
    # stale hash (evicted flow): result must be dropped
    state = ft.apply_inference_result(state, slot, jnp.asarray(2),
                                      h + jnp.uint32(1))
    assert int(state["cls"][slot]) == 5
