"""Data Engine invariants: flow tracking, ring semantics, token bucket.

Includes a python-oracle simulation of the switch pipeline and hypothesis
property tests of the system invariants (bucket bounds, grant rate <= V,
ring = last-8 window)."""

import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.data_engine import engine as de
from repro.core.data_engine.state import (EngineConfig, hash_five_tuple,
                                          init_state, make_packets)

CFG = EngineConfig(n_slots_log2=8, ring_depth=8)


def _stream(rng, n_pkts, n_flows, rate_us=50):
    flows = [{
        "src_ip": np.uint32(rng.integers(1, 2**31)),
        "dst_ip": np.uint32(rng.integers(1, 2**31)),
        "src_port": np.uint32(rng.integers(1, 65535)),
        "dst_port": np.uint32(rng.integers(1, 65535)),
        "proto": np.uint32(6),
    } for _ in range(n_flows)]
    pk = {k: np.empty(n_pkts, np.uint32) for k in flows[0]}
    pk["ts_us"] = np.sort(rng.integers(0, n_pkts * rate_us, n_pkts)
                          ).astype(np.int32)
    pk["pkt_len"] = rng.integers(40, 1500, n_pkts).astype(np.int32)
    fidx = rng.integers(0, n_flows, n_pkts)
    for k in flows[0]:
        pk[k] = np.asarray([flows[i][k] for i in fidx], np.uint32)
    return pk, fidx


def test_flow_tracker_new_flow_counting():
    rng = np.random.default_rng(0)
    pk, fidx = _stream(rng, 500, 37)
    state = init_state(CFG)
    state, out = de.process_batch(state, {k: jnp.asarray(v)
                                          for k, v in pk.items()}, CFG)
    # new-flow count == distinct slots touched (modulo collisions)
    n_new = int(np.sum(np.asarray(out["is_new"])))
    slots = set(np.asarray(out["slot"]).tolist())
    assert n_new >= len(slots)          # collisions re-init entries
    assert int(state["win_pkt_cnt"]) == 500


def test_ring_holds_last_depth_features():
    """Ring contents == last `depth` packet features of the flow (oracle)."""
    rng = np.random.default_rng(1)
    pk, fidx = _stream(rng, 400, 3)     # few flows => deep rings
    state = init_state(CFG)
    state, out = de.process_batch(state, {k: jnp.asarray(v)
                                          for k, v in pk.items()}, CFG)
    # python oracle: last 8 (len, ipd) per flow — only when no collisions
    slots = np.asarray(out["slot"])
    ring = np.asarray(state["ring"])
    buff_idx = np.asarray(state["buff_idx"])
    hist = collections.defaultdict(list)
    last_ts = {}
    for i in range(len(fidx)):
        fi = int(fidx[i])
        ipd = pk["ts_us"][i] - last_ts.get(fi, pk["ts_us"][i])
        hist[fi].append((int(pk["pkt_len"][i]), max(int(ipd), 0)))
        last_ts[fi] = pk["ts_us"][i]
    for fi in set(fidx.tolist()):
        slot = int(slots[fidx == fi][0])
        want = hist[fi][-CFG.ring_depth:]
        idx = int(buff_idx[slot])
        order = [(idx + j) % CFG.ring_depth for j in range(CFG.ring_depth)]
        got = [tuple(ring[slot, o]) for o in order][-len(want):]
        assert [tuple(map(int, g)) for g in got] == want, fi


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(50, 300))
def test_token_bucket_invariants(seed, n):
    rng = np.random.default_rng(seed)
    pk, _ = _stream(rng, n, 11)
    state = init_state(CFG)
    state, out = de.process_batch(state, {k: jnp.asarray(v)
                                          for k, v in pk.items()}, CFG)
    bucket = int(state["bucket"])
    assert 0 <= bucket <= CFG.bucket_cap_us
    # grants bounded by refill + initial capacity
    span = int(pk["ts_us"][-1]) - int(pk["ts_us"][0])
    max_grants = (span + CFG.bucket_cap_us) // CFG.cost_us + 1
    assert int(state["granted"]) <= max_grants


def test_fast_mode_matches_scan_grant_rate():
    """Vectorized admission approximates the exact scan within 20% grants."""
    rng = np.random.default_rng(3)
    pk, _ = _stream(rng, 1024, 64, rate_us=200)
    jb = {k: jnp.asarray(v) for k, v in pk.items()}
    s1, o1 = de.process_batch(init_state(CFG), dict(jb), CFG)
    s2, o2 = de.process_batch_fast(init_state(CFG), dict(jb), CFG)
    g1 = int(np.sum(np.asarray(o1["granted"])))
    g2 = int(np.sum(np.asarray(o2["granted"])))
    assert g1 > 0 and g2 > 0
    assert abs(g1 - g2) <= max(0.25 * g1, 8), (g1, g2)


def test_classification_result_application():
    from repro.core.data_engine import flow_tracker as ft
    state = init_state(CFG)
    h = hash_five_tuple(*(jnp.asarray(x, jnp.uint32)
                          for x in (1, 2, 3, 4, 6)))
    slot = (h & jnp.uint32(CFG.n_slots - 1)).astype(jnp.int32)
    state["hash"] = state["hash"].at[slot].set(h)
    state = ft.apply_inference_result(state, slot, jnp.asarray(5), h)
    assert int(state["cls"][slot]) == 5
    # stale hash (evicted flow): result must be dropped
    state = ft.apply_inference_result(state, slot, jnp.asarray(2),
                                      h + jnp.uint32(1))
    assert int(state["cls"][slot]) == 5
