"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the repo convention; full
JSON artifacts land in benchmarks/results/.

  throughput   — data-plane pps at batch 4096 (segment vs seed dense path)
  pipes        — multi-pipeline pps sweep (num_pipes x batch, ISSUE 2)
  engines      — Model-Engine farm sweep at E in {1,2,4} (ISSUE 3; bar:
                 E=2 >= 1.7x served inferences/s over E=1 at saturation)
  oversub      — Figure 10 analogue at batch 8192 (F1 + pps vs offered
                 load past the Model-Engine service capacity)
  traces       — real-trace replay (ISSUE 4): pcap fixture -> streaming
                 ingest (bit-identity oracle) -> all four drivers
                 (host/device/pipes/farm) via run_trace(trace=...)
  soak         — sustained streaming replay (ISSUE 9): double-buffered
                 ingest vs sync staging vs the per-window host-sync
                 loop; steady-state pps, zero-host-sync assertion, RSS
  accuracy     — Table 2 (macro-F1, 9 schemes x 2 tasks)
  resource     — Tables 3+4 (SRAM/VMEM/MAC proxies)
  scalability  — Figure 10 (F1 vs concurrency/throughput)
  latency      — Figure 11 (FPGA cycle model, TPU roofline, CPU measured)
  fairness     — Appendix A (E[interval] == N/V)
  roofline     — §Roofline table from the dry-run artifacts (if present)

``python -m benchmarks.run [--fast] [--only section[,section...]]``
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks._io import write_json_atomic

RESULTS = os.path.join(os.path.dirname(__file__), "results")

SECTIONS = ("throughput", "gate", "pipes", "engines", "oversub", "traces",
            "soak", "accuracy", "resource", "scalability", "latency",
            "fairness", "roofline")


def _row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.2f},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller accuracy/scalability settings")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(SECTIONS))
    args, _ = ap.parse_known_args()
    os.makedirs(RESULTS, exist_ok=True)
    only = args.only.split(",") if args.only else None
    if only:
        unknown = sorted(set(only) - set(SECTIONS))
        if unknown:
            ap.error(f"unknown --only section(s): {', '.join(unknown)}; "
                     f"valid sections: {', '.join(SECTIONS)}")

    def want(name):
        return only is None or name in only

    print("name,us_per_call,derived")

    if want("throughput"):
        from benchmarks import bench_scalability
        n_b = 4 if args.fast else 12
        res = bench_scalability.throughput(n_batches=n_b)
        write_json_atomic(os.path.join(RESULTS, "throughput.json"), res)
        _row("fastpath_throughput", res["segment"]["us_per_batch"],
             f"pps={res['segment']['pps']:.0f};"
             f"speedup_vs_dense={res['speedup_vs_dense']:.1f}x")

    if want("gate"):
        from benchmarks import bench_gate
        iters, interp = (20, 1) if args.fast else (50, 3)
        res = bench_gate.sweep(iters=iters, interp_iters=interp)
        write_json_atomic(os.path.join(RESULTS, "gate.json"), res)
        for r in res["rows"]:
            _row(f"gate_b{r['batch_size']}_p{r['num_pipes']}",
                 r["fused_us"],
                 f"unfused_us={r['unfused_us']};"
                 f"speedup_fused={r['speedup_fused']:.2f}x;"
                 f"granted={r['granted']}")

    if want("pipes"):
        from benchmarks import bench_scalability
        sizes = (4096,) if args.fast else (4096, 8192)
        steps = 4 if args.fast else 8
        rows = bench_scalability.pipes_sweep(batch_sizes=sizes,
                                             n_steps=steps)
        write_json_atomic(os.path.join(RESULTS, "pipes.json"),
                          {"rows": rows})
        for r in rows:
            _row(f"pipes_p{r['num_pipes']}_b{r['batch_size']}",
                 r["wall_s"] * 1e6 / max(r["packets"] // r["batch_size"], 1),
                 f"pps={r['pps']:.0f};"
                 f"speedup_vs_1pipe={r['speedup_vs_1pipe']:.2f}x;"
                 f"sharded={r['sharded']}")

    if want("engines"):
        from benchmarks import bench_scalability
        steps = 192 if args.fast else 512
        rows = bench_scalability.engines_sweep(engines=(1, 2, 4),
                                               n_steps=steps)
        write_json_atomic(os.path.join(RESULTS, "engines.json"),
                          {"rows": rows})
        for r in rows:
            _row(f"engines_e{r['num_engines']}", r["wall_s"] * 1e6,
                 f"served_per_s={r['served_inf_per_s']:.0f};"
                 f"speedup_vs_1eng={r['speedup_vs_1eng']:.2f}x;"
                 f"sharded={r['sharded']}")

    if want("oversub"):
        from benchmarks import bench_scalability
        t0 = time.time()
        if args.fast:
            res = bench_scalability.oversub_sweep(
                oversubs=(0.5, 16.0), n_flows=250, pkts=20_000,
                train_steps=150, train_flows=250)
        else:
            res = bench_scalability.oversub_sweep()
        write_json_atomic(os.path.join(RESULTS, "oversub.json"), res)
        rows = res["rows"]
        _row("oversub", (time.time() - t0) * 1e6,
             f"f1_lo={rows[0]['macro_f1']:.3f};"
             f"f1_hi={rows[-1]['macro_f1']:.3f};"
             f"rel_drop={res['rel_f1_drop']:.3f};"
             f"pps={rows[-1]['pps_wall']:.0f}")

    if want("traces"):
        from benchmarks import bench_traces
        t0 = time.time()
        res = bench_traces.main(
            out_path=os.path.join(RESULTS, "traces.json"),
            fast=args.fast)
        for r in res["rows"]:
            _row(f"traces_{r['driver']}", r["wall_s"] * 1e6,
                 f"pps={r['pps_wall']:.0f};"
                 f"served_per_s={r['served_inf_per_s']:.0f};"
                 f"classified_frac={r['classified_frac']:.3f}")
        _row("traces_total", (time.time() - t0) * 1e6,
             f"packets={res['rows'][0]['packets']};"
             f"source={res['source']}")

    if want("soak"):
        from benchmarks import bench_soak
        t0 = time.time()
        res = bench_soak.main(
            out_path=os.path.join(RESULTS, "soak.json"), fast=args.fast)
        _row("soak", (time.time() - t0) * 1e6,
             f"steady_pps={res['overlap']['steady_pps']:.0f};"
             f"overlap_speedup={res['overlap_speedup']:.2f}x;"
             f"zerosync_speedup={res['zerosync_speedup']:.2f}x;"
             f"host_syncs={res['overlap']['host_syncs']};"
             f"rss_growth_mb={res['overlap']['rss_growth_mb']}")

    if want("accuracy"):
        from benchmarks import bench_accuracy
        t0 = time.time()
        n, s = (250, 150) if args.fast else (700, 350)
        res = bench_accuracy.main(n_flows=n, steps=s,
                                  out_path=os.path.join(RESULTS,
                                                        "accuracy.json"))
        for task in ("iscx", "ustc"):
            best = res[task]["fenix-rnn-flow"]["macro_f1"]
            pkt = res[task]["fenix-cnn-pkt"]["macro_f1"]
            _row(f"accuracy_{task}", (time.time() - t0) * 1e6,
                 f"fenix_flow_f1={best:.3f};fenix_pkt_f1={pkt:.3f}")

    if want("resource"):
        from benchmarks import bench_resource
        t0 = time.time()
        res = bench_resource.main(os.path.join(RESULTS, "resource.json"))
        _row("resource", (time.time() - t0) * 1e6,
             f"sram_frac={res['data_engine']['sram_fraction_tofino2']:.4f}")

    if want("scalability"):
        from benchmarks import bench_scalability
        t0 = time.time()
        scales = ((1000, 0.5), (1000, 16.0)) if args.fast else \
            ((1000, 0.5), (1000, 4.0), (1000, 16.0), (1000, 64.0),
             (4000, 16.0), (8000, 16.0))
        rows = bench_scalability.main(
            os.path.join(RESULTS, "scalability.json"), scales=scales,
            include_throughput=False)
        drop = (rows[0]["macro_f1"] - rows[-1]["macro_f1"]) \
            / max(rows[0]["macro_f1"], 1e-9)
        _row("scalability", (time.time() - t0) * 1e6,
             f"f1_small={rows[0]['macro_f1']:.3f};"
             f"f1_large={rows[-1]['macro_f1']:.3f};rel_drop={drop:.3f}")

    if want("latency"):
        from benchmarks import bench_latency
        t0 = time.time()
        res = bench_latency.main(os.path.join(RESULTS, "latency.json"))
        us = res["fenix-cnn"]["fpga_cycle_model_us"]
        _row("latency_fenix_cnn", us,
             f"speedup_vs_ctrl={res['fenix-cnn']['speedup_vs_control_plane']:.0f}x")
        _row("latency_fenix_rnn", res["fenix-rnn"]["fpga_cycle_model_us"],
             f"tpu_roofline_us="
             f"{res['fenix-rnn']['tpu_roofline']['latency_us']:.2f}")

    if want("fairness"):
        from benchmarks import bench_fairness
        t0 = time.time()
        rows = bench_fairness.main(os.path.join(RESULTS, "fairness.json"))
        _row("fairness", (time.time() - t0) * 1e6,
             f"max_rel_err={max(r['rel_err'] for r in rows):.3f}")

    if want("roofline"):
        from repro.launch import roofline
        t0 = time.time()
        try:
            cells = roofline.load_cells("baseline")
            ok = [c for c in cells if c.get("status") == "ok"]
            if ok:
                worst = min(ok, key=lambda c: c.get("useful_ratio", 1.0))
                _row("roofline", (time.time() - t0) * 1e6,
                     f"cells={len(ok)};worst_ratio="
                     f"{worst['useful_ratio']:.2f}@"
                     f"{worst['arch']}x{worst['shape']}")
                write_json_atomic(os.path.join(RESULTS, "roofline.json"),
                                  cells, default=str)
        except Exception as e:  # dry-run artifacts absent
            _row("roofline", 0.0, f"skipped({e})")


if __name__ == "__main__":
    main()
