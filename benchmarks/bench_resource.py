"""Tables 3+4 analogue: resource overhead of the Data/Model Engines.

Switch-side (Table 3 proxy): SRAM bytes of the Flow Info Table + rings +
LUT vs alternatives' published footprints; pipeline-stage count proxy =
number of sequential integer ops per packet.

FPGA-side (Table 4 proxy): per-module MAC counts, weight bytes and VMEM
working set of the INT8 kernels (LUT/FF/BRAM/DSP analogue on TPU).
"""

from __future__ import annotations

from typing import Dict

from benchmarks._io import write_json_atomic
from repro.configs.fenix_models import fenix_cnn, fenix_rnn
from repro.core.data_engine.state import EngineConfig
from repro.core.model_engine.inference import macs_per_inference

TOFINO2_SRAM_BITS = 200e6      # per pipeline (paper §6)


def data_engine_resources(cfg: EngineConfig) -> Dict[str, float]:
    n = cfg.n_slots
    flow_table = n * (4 + 4 + 4 + 4 + 4 + 4 + 4)    # 7 int32 fields
    rings = n * cfg.ring_depth * cfg.feat_dim * 4
    lut = cfg.lut.t_bins * cfg.lut.c_bins * 4
    total = flow_table + rings + lut
    return {
        "flow_table_bytes": flow_table,
        "ring_bytes": rings,
        "lut_bytes": lut,
        "total_sram_bytes": total,
        "sram_fraction_tofino2": total * 8 / TOFINO2_SRAM_BITS,
        # pipeline stages: hash, lookup, stats, LUT, bucket, ring, deparse
        "stage_proxy": 7,
        "tcam_entries": 0,  # the preliminary tree is compare-only (SRAM)
    }


def model_engine_resources() -> Dict[str, Dict[str, float]]:
    out = {}
    for mk in (fenix_cnn, fenix_rnn):
        cfg = mk(12)
        macs = macs_per_inference(cfg)
        e = cfg.embed_dim
        emb_bytes = (cfg.len_buckets + cfg.ipd_buckets) * e  # int8
        if cfg.kind == "cnn":
            w = 0
            c_prev = 2 * e
            for ch in cfg.conv_filters:
                w += cfg.conv_kernel * c_prev * ch
                c_prev = ch
            f_prev = c_prev
            for fc in cfg.fc_dims:
                w += f_prev * fc
                f_prev = fc
            w += f_prev * cfg.num_classes
        else:
            u = cfg.rnn_units
            w = (2 * e) * u + u * u + u * cfg.num_classes
        out[cfg.name] = {
            "macs_per_window": macs,
            "weight_bytes_int8": w,
            "embed_bytes_int8": emb_bytes,
            "vmem_working_set_bytes": w + emb_bytes + 128 * 128 * 4,
            "vmem_fraction_v5e": (w + emb_bytes) / (128 * 2**20),
        }
    return out


# published Table 3 numbers for context (from the paper)
PAPER_TABLE3 = {
    "FENIX": {"SRAM": 0.129, "TCAM": 0.044, "Stage": 9},
    "FlowLens": {"SRAM": 0.342, "TCAM": 0.0, "Stage": 9},
    "BoS": {"SRAM": 0.263, "TCAM": 0.063, "Stage": 12},
    "Leo": {"SRAM": 0.269, "TCAM": 0.09, "Stage": 12},
    "NetBeacon": {"SRAM": 0.116, "TCAM": 0.188, "Stage": 12},
}


def main(out_path: str = None) -> Dict:
    res = {
        "data_engine": data_engine_resources(EngineConfig()),
        "data_engine_64k_flows": data_engine_resources(
            EngineConfig(n_slots_log2=16)),
        "model_engine": model_engine_resources(),
        "paper_table3_published": PAPER_TABLE3,
    }
    if out_path:
        write_json_atomic(out_path, res)
    return res


if __name__ == "__main__":
    import pprint
    pprint.pprint(main())
