"""Table 2 analogue: macro-F1 of all 9 schemes on both tasks.

FENIX flow/packet-level CNN+RNN (float-trained, INT8-deployed) vs FlowLens,
NetBeacon, Leo, BoS, N3IC on the synthetic ISCX-like and USTC-like datasets
(DESIGN.md §7: relative comparison on identical data).

Real traces: pass ``sources={"iscx": capture, ...}`` (or ``--source`` on
the CLI) to train/evaluate every scheme on an ingested pcap or CSV export
instead of the parametric generators — flows come from
``repro.data.trace_ingest.load_flows`` through the task's schema adapter.
"""

from __future__ import annotations

import time
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from benchmarks._io import write_json_atomic
from repro.baselines import bos as bos_lib
from repro.baselines import n3ic as n3ic_lib
from repro.baselines.common import confusion_matrix, flow_vote, macro_f1
from repro.baselines.flowlens import FlowLensModel, markers
from repro.baselines.leo import LeoModel
from repro.baselines.netbeacon import NetBeaconModel
from repro.configs.fenix_models import fenix_cnn, fenix_rnn
from repro.data.synthetic_traffic import (class_weights, make_flows, task_meta,
                                          windows_from_flows)
from repro.models import traffic
from repro.quant.quantize import int8_apply, quantize_traffic
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig, batch_iterator


def _split_flows(flows, test_frac=0.25, seed=0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(flows))
    n_test = int(len(flows) * test_frac)
    te = [flows[i] for i in idx[:n_test]]
    tr = [flows[i] for i in idx[n_test:]]
    return tr, te


def _train_nn(loss_fn, params, x, y, steps, n_classes, lr=3e-3, seed=0):
    w = class_weights(y, n_classes)
    t = Trainer(loss_fn, params,
                TrainerConfig(total_steps=steps, log_every=10**9,
                              opt=OptConfig(lr=lr, warmup_steps=steps // 10,
                                            total_steps=steps,
                                            weight_decay=0.01)))
    t.run(batch_iterator(x, y, 256, seed=seed, weights=w))
    return t.params


DEFAULT_ADAPTERS = {"iscx": "iscx_vpn", "ustc": "ustc_tfc"}


def run_task(task: str, n_flows: int = 500, steps: int = 300,
             seed: int = 0, source=None,
             adapter: Optional[str] = None) -> Dict[str, Dict[str, float]]:
    classes, _ = task_meta(task)
    k = len(classes)
    out_classes = list(classes)
    if source is not None:
        from repro.data.trace_ingest import load_flows

        flows = load_flows(source,
                           adapter=adapter or DEFAULT_ADAPTERS[task])
        bad = [f for f in flows if not 0 <= f.label < k]
        if bad:
            raise ValueError(
                f"{len(bad)} of {len(flows)} flows in {source} carry no "
                f"valid {task} label (need a ground-truth sidecar or a "
                f"labeled CSV)")
    else:
        flows = make_flows(task, n_flows, seed=seed, min_per_class=30)
    tr_flows, te_flows = _split_flows(flows, seed=seed)
    xtr, ytr, ftr = windows_from_flows(tr_flows, seed=seed)
    xte, yte, fte = windows_from_flows(te_flows, seed=seed + 1)
    out: Dict[str, Dict[str, float]] = {}

    # ---- FENIX CNN / RNN (packet + flow level), INT8-deployed ----
    for mk, nm in ((fenix_cnn, "fenix-cnn"), (fenix_rnn, "fenix-rnn")):
        cfg = mk(k)
        params = traffic.init(cfg, seed=seed)
        params = _train_nn(lambda p, b: traffic.loss_fn(p, cfg, b), params,
                           xtr, ytr, steps, k)
        qp = quantize_traffic(params, cfg, jnp.asarray(xtr[:512]))
        pred = np.asarray(jnp.argmax(
            int8_apply(qp, cfg, jnp.asarray(xte)), -1))
        pkt_f1 = macro_f1(yte, pred, k)
        uf, votes = flow_vote(pred, fte)
        flow_labels = np.asarray([yte[fte == f][0] for f in uf])
        flow_f1 = macro_f1(flow_labels, votes, k)
        # per-class confusion in the artifact: a macro-F1 riding one
        # majority class shows up as empty off-diagonal rows here (the
        # regression gate reads macro_f1 only and ignores these keys)
        out[f"{nm}-pkt"] = {
            "macro_f1": pkt_f1,
            "confusion": confusion_matrix(yte, pred, k).tolist()}
        out[f"{nm}-flow"] = {
            "macro_f1": flow_f1,
            "confusion": confusion_matrix(flow_labels, votes,
                                          k).tolist()}

    # ---- FlowLens (flow-level only) ----
    xf, yf = markers(tr_flows)
    xfe, yfe = markers(te_flows)
    fl = FlowLensModel(k)
    fl.fit(xf, yf)
    out["flowlens-flow"] = {"macro_f1": macro_f1(yfe, fl.predict(xfe), k)}

    # ---- Leo ----
    leo = LeoModel(k)
    leo.fit(tr_flows)
    r = leo.predict_packets(te_flows)
    out["leo-pkt"] = {"macro_f1": macro_f1(r["label"], r["pred"], k)}

    # ---- NetBeacon ----
    nb = NetBeaconModel(k, seed=seed)
    nb.fit(tr_flows)
    r = nb.predict_packets(te_flows)
    out["netbeacon-pkt"] = {"macro_f1": macro_f1(r["label"], r["pred"], k)}

    # ---- BoS ----
    cfg = fenix_cnn(k)  # reuse embedding sizes
    params = bos_lib.init(cfg, seed=seed)
    params = _train_nn(lambda p, b: bos_lib.loss_fn(p, cfg, b), params,
                       xtr, ytr, steps, k)
    pred = np.asarray(jnp.argmax(bos_lib.apply(params, cfg,
                                               jnp.asarray(xte)), -1))
    out["bos-pkt"] = {"macro_f1": macro_f1(yte, pred, k)}

    # ---- N3IC ----
    xn, yn, fn_ = n3ic_lib.build_features(tr_flows)
    xne, yne, fne = n3ic_lib.build_features(te_flows)
    params = n3ic_lib.init(xn.shape[1], k, seed=seed)
    wts = class_weights(yn, k)

    def n3ic_batches():
        rng = np.random.default_rng(seed)
        while True:
            idx = rng.integers(0, len(yn), 256)
            yield {"payload": jnp.asarray(xn[idx]),
                   "label": jnp.asarray(yn[idx]),
                   "weight": jnp.asarray(wts[idx], jnp.float32)}

    t = Trainer(lambda p, b: n3ic_lib.loss_fn(p, b), params,
                TrainerConfig(total_steps=steps, log_every=10**9,
                              opt=OptConfig(lr=3e-3,
                                            warmup_steps=steps // 10,
                                            total_steps=steps,
                                            weight_decay=0.01)))
    t.run(n3ic_batches())
    pred = np.asarray(jnp.argmax(n3ic_lib.apply(t.params,
                                                jnp.asarray(xne)), -1))
    out["n3ic-pkt"] = {"macro_f1": macro_f1(yne, pred, k)}
    # class-name legend for the confusion matrices (row/col order); a
    # list, so the regression-gate extractor skips it
    out["_classes"] = out_classes
    return out


def main(n_flows: int = 500, steps: int = 300, out_path: str = None,
         sources: Optional[Dict[str, str]] = None,
         adapters: Optional[Dict[str, str]] = None):
    sources, adapters = sources or {}, adapters or {}
    results = {}
    for task in ("iscx", "ustc"):
        t0 = time.time()
        results[task] = run_task(task, n_flows=n_flows, steps=steps,
                                 source=sources.get(task),
                                 adapter=adapters.get(task))
        results[task]["_wall_s"] = round(time.time() - t0, 1)
    if out_path:
        write_json_atomic(out_path, results)
    return results


if __name__ == "__main__":
    import argparse
    import pprint

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--task", choices=("iscx", "ustc"), default=None,
                    help="limit to one task (required with --source)")
    ap.add_argument("--source", default=None,
                    help="capture (pcap/CSV) to use instead of synthetic")
    ap.add_argument("--adapter", default=None,
                    help="CSV schema adapter for --source")
    ap.add_argument("--n-flows", type=int, default=500)
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    if args.source and not args.task:
        ap.error("--source requires --task")
    if args.task:
        pprint.pprint({args.task: run_task(
            args.task, n_flows=args.n_flows, steps=args.steps,
            source=args.source, adapter=args.adapter)})
    else:
        pprint.pprint(main(n_flows=args.n_flows, steps=args.steps))
