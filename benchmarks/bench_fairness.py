"""Appendix A validation: E[transmission interval] == N/V, empirically.

Monte-carlo over heterogeneous flow-rate mixes; also reports the per-rate
expected periods (Eq. 6) vs simulation — the mechanism that keeps slow
flows sampled under load (the paper's fairness argument)."""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks._io import write_json_atomic
from repro.core.probability import expected_period, probability


def simulate(rates: np.ndarray, v: float, horizon: float,
             seed: int = 0) -> Dict:
    rng = np.random.default_rng(seed)
    n = len(rates)
    q = rates.sum()
    intervals_all: List[float] = []
    per_rate: Dict[float, List[float]] = {}
    for qi in rates:
        t_last, c, t = 0.0, 0, 0.0
        ivs = []
        while t < horizon:
            t += rng.exponential(1.0 / qi)
            c += 1
            p = probability(np.asarray([t - t_last]), np.asarray([c]),
                            n, q, v)[0]
            if rng.random() < p:
                ivs.append(t - t_last)
                t_last, c = t, 0
        intervals_all.extend(ivs)
        per_rate.setdefault(round(qi, 6), []).extend(ivs)
    return {
        "measured_mean": float(np.mean(intervals_all)),
        "expected_nv": n / v,
        "per_rate": {str(k): {"measured": float(np.mean(v_)),
                              "eq6": expected_period(k, n, q, v)}
                     for k, v_ in per_rate.items() if v_},
    }


def main(out_path: str = None) -> List[Dict]:
    rows = []
    for name, rates in (
        ("uniform", np.full(50, 0.01)),
        ("bimodal_10x", np.concatenate([np.full(25, 0.002),
                                        np.full(25, 0.02)])),
        ("lognormal", np.random.default_rng(0).lognormal(-5, 1.0, 50)),
    ):
        q = rates.sum()
        v = q / 10.0
        r = simulate(rates, v, horizon=2_000_000)
        r["mix"] = name
        r["rel_err"] = abs(r["measured_mean"] - r["expected_nv"]) \
            / r["expected_nv"]
        rows.append(r)
        print(f"{name}: measured {r['measured_mean']:.0f} vs N/V "
              f"{r['expected_nv']:.0f} (rel err {r['rel_err']:.3f})")
    if out_path:
        write_json_atomic(out_path, rows)
    return rows


if __name__ == "__main__":
    main()
