"""Figure 10 analogue: accuracy under flow-concurrency / throughput scale.

Sweeps concurrent flows (and implied aggregate packet rate) through the
FENIX co-simulation (fast vectorized data plane + INT8 model engine);
reports macro-F1 of DNN-classified flows at each scale.  The paper observes
a graceful ~13% relative F1 drop at the largest (Tbps) scale — driven by
rate-limited sampling giving each flow fewer/staler inference windows —
which is exactly the mechanism simulated here.
"""

from __future__ import annotations

import json
import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.baselines.common import flow_vote, macro_f1
from repro.configs.fenix_models import fenix_cnn
from repro.core.fenix import FenixConfig, FenixSystem
from repro.core.data_engine.state import EngineConfig
from repro.core.model_engine.inference import EngineModel
from repro.data.synthetic_traffic import (make_flows, packet_stream,
                                          windows_from_flows)
from repro.models import traffic
from repro.quant.quantize import quantize_traffic
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig, batch_iterator


def throughput(batch_size: int = 4096, n_batches: int = 12,
               seed: int = 0, include_dense: bool = True,
               include_pallas: bool = True) -> Dict:
    """Data-plane packets/sec of ``process_batch_fast`` at ``batch_size``.

    Compares the O(n log n) sort/segment admission path against the seed's
    O(n^2) dense backlog count (``dense_backlog=True``) and the Pallas
    rate-gate backend (interpret mode on CPU).  The acceptance bar for the
    device-resident fast path is >= 3x pps over the dense seed path at
    batch_size=4096 on CPU.
    """
    import jax

    from repro.core.data_engine import engine as de
    from repro.core.data_engine.state import init_state, make_packets

    rng = np.random.default_rng(seed)
    pk = make_packets(rng, batch_size)
    jb = {k: jnp.asarray(v) for k, v in pk.items()}
    modes = [("segment", EngineConfig())]
    if include_pallas:
        modes.append(("pallas_gate", EngineConfig(gate_backend="pallas")))
    if include_dense:
        modes.append(("dense_seed", EngineConfig(dense_backlog=True)))
    res: Dict = {"batch_size": batch_size}
    for name, ecfg in modes:
        state = init_state(ecfg)
        state, out = de.process_batch_fast(state, dict(jb), ecfg)
        jax.block_until_ready(out["granted"])          # compile
        t0 = time.perf_counter()
        for _ in range(n_batches):
            state, out = de.process_batch_fast(state, dict(jb), ecfg)
        jax.block_until_ready(out["granted"])
        dt = (time.perf_counter() - t0) / n_batches
        res[name] = {"us_per_batch": dt * 1e6, "pps": batch_size / dt}
    if include_dense:
        res["speedup_vs_dense"] = (res["segment"]["pps"]
                                   / res["dense_seed"]["pps"])
    return res


class _LenModel:
    """Trivial deterministic Model Engine (class = F9 pkt_len mod 7) so the
    pipes sweep times the sharded data plane + merge, not DNN FLOPs."""

    num_classes = 7

    def infer(self, payload):
        return (payload[:, -1, 0] % self.num_classes).astype(jnp.int32)


def _balanced_stream(num_pipes: int, per_pipe: int, seed: int) -> Dict:
    """Synthetic packet stream with exactly ``per_pipe`` packets per pipe.

    Random 5-tuples are generated with ~50% headroom, then trimmed so every
    pipeline owns exactly ``per_pipe`` packets (ECMP-balanced ingress) —
    the sweep measures the steady-state sharded scan, not skew tails.
    """
    from repro.core.data_engine.state import (EngineConfig, hash_five_tuple,
                                              make_packets, pipe_of_hash)
    import jax.numpy as _jnp

    rng = np.random.default_rng(seed)
    n = num_pipes * per_pipe
    over = n + n // 2 + 4096
    pk = make_packets(rng, over)
    pk["ts_us"] = np.sort(rng.integers(0, n * 10, over)).astype(np.int32)
    h = np.asarray(hash_five_tuple(
        _jnp.asarray(pk["src_ip"]), _jnp.asarray(pk["dst_ip"]),
        _jnp.asarray(pk["src_port"]), _jnp.asarray(pk["dst_port"]),
        _jnp.asarray(pk["proto"])))
    pipe = pipe_of_hash(h, EngineConfig(), num_pipes)
    keep = np.zeros(over, bool)
    for p in range(num_pipes):
        mine = np.flatnonzero(pipe == p)
        if len(mine) < per_pipe:
            raise ValueError("headroom too small for balanced trim")
        keep[mine[:per_pipe]] = True
    return {k: v[keep] for k, v in pk.items()}


def pipes_sweep(batch_sizes=(4096, 8192), pipes=(1, 2, 4),
                n_steps: int = 8, seed: int = 0) -> List[Dict]:
    """Multi-pipeline throughput: pps at num_pipes x per-pipe batch size.

    Each pipeline ingests ``batch_size`` packets per step (its own line
    rate), so a P-pipe run pushes P x batch_size x n_steps packets through
    the sharded ``run_trace`` driver; ``num_pipes=1`` is the unsharded
    device driver the acceptance bar compares against.  One warm run
    compiles, a second (after ``reset()``) is timed.
    """
    import time as _time

    from repro.core.data_engine.state import EngineConfig
    from repro.core.fenix import FenixConfig, FenixSystem
    from repro.core.model_engine.vector_io import IOConfig

    rows: List[Dict] = []
    for bs in batch_sizes:
        base_pps = None
        for p in pipes:
            n = p * bs * n_steps
            pk = _balanced_stream(p, bs * n_steps, seed)
            sys_ = FenixSystem(
                FenixConfig(engine=EngineConfig(),
                            io=IOConfig(serve_max=128),
                            batch_size=bs, control_plane_every=10**9,
                            num_pipes=p), _LenModel())
            sys_.run_trace(pk)                     # compile + warm
            sys_.reset()
            t0 = _time.perf_counter()
            sys_.run_trace(pk)
            dt = _time.perf_counter() - t0
            row = {"num_pipes": p, "batch_size": bs, "packets": n,
                   "pps": n / dt, "wall_s": round(dt, 3),
                   "devices": min(p, len(__import__("jax").devices())),
                   "sharded": sys_._mesh is not None}
            if base_pps is None:        # first pipe count is the baseline
                base_pps, base_p = row["pps"], p
            row["baseline_pipes"] = base_p
            row["speedup_vs_1pipe"] = row["pps"] / base_pps
            rows.append(row)
            print(row, flush=True)
    return rows


def train_model(seed=0, steps=300, n_flows=400):
    flows = make_flows("iscx", n_flows, seed=seed, min_per_class=20)
    x, y, _ = windows_from_flows(flows)
    cfg = fenix_cnn(7)
    params = traffic.init(cfg, seed)
    t = Trainer(lambda p, b: traffic.loss_fn(p, cfg, b), params,
                TrainerConfig(total_steps=steps, log_every=10**9,
                              opt=OptConfig(lr=3e-3,
                                            warmup_steps=steps // 10,
                                            total_steps=steps)))
    t.run(batch_iterator(x, y, 256))
    qp = quantize_traffic(t.params, cfg, jnp.asarray(x[:512]))
    return cfg, qp


def run_scale(cfg, qp, n_flows: int, pkts: int = 60_000,
              seed: int = 1, oversub: float = 1.0) -> Dict:
    """oversub = aggregate packet rate / Model-Engine service rate V.

    This is Figure 10's x-axis: the paper pushes traffic past the FPGA's
    capacity (1000 Mpps offered vs 75 Mpps served ~ 13x); we set the
    engine's service rate so the same ratio holds at simulation scale.
    """
    flows = make_flows("iscx", n_flows, seed=seed, min_per_class=10,
                       duration_s=10.0)
    stream = packet_stream(flows, limit=pkts)
    span_us = max(int(stream["ts_us"][-1] - stream["ts_us"][0]), 1)
    pps = pkts / (span_us / 1e6)
    fpga_hz = max(pps / max(oversub, 1e-6), 1.0)
    oracle = [np.stack([f.pkt_len, f.ipd_us], -1).astype(np.int32)
              for f in flows]
    model = EngineModel(cfg, qp)
    sys_ = FenixSystem(FenixConfig(
        engine=EngineConfig(
            fpga_hz=fpga_hz,
            n_slots_log2=max(12, int(np.ceil(
                np.log2(max(n_flows * 4, 2)))))),
        fast_mode=True), model, oracle_windows=oracle)
    out = sys_.run_trace(stream)
    # flow-level macro-F1 over flows that received a DNN verdict
    v = out["verdict"]
    ok = v >= 0
    labels = stream["label"]
    fidx = stream["flow_idx"]
    if ok.sum() == 0:
        return {"n_flows": n_flows, "macro_f1": 0.0, "coverage": 0.0}
    uf, votes = flow_vote(v[ok], fidx[ok])
    flow_labels = np.asarray([labels[fidx == f][0] for f in uf])
    f1 = macro_f1(flow_labels, votes, 7)
    return {"n_flows": n_flows, "oversub": oversub, "macro_f1": f1,
            "coverage": float(ok.mean()),
            "granted": sys_.stats["granted"],
            "grant_frac": sys_.stats["granted"] / pkts,
            "inferences": sys_.stats["inferences"]}


def main(out_path: str = None,
         scales=((1000, 0.5), (1000, 4.0), (1000, 16.0), (1000, 64.0),
                 (4000, 16.0), (8000, 16.0)),
         include_throughput: bool = True) -> List:
    # run.py measures throughput as its own row; it passes
    # include_throughput=False here to avoid paying for the sweep twice
    tp = throughput() if include_throughput else None
    if tp is not None:
        print({"fastpath": tp}, flush=True)
    cfg, qp = train_model()
    rows = []
    for n, oversub in scales:
        t0 = time.time()
        r = run_scale(cfg, qp, n, oversub=oversub)
        r["wall_s"] = round(time.time() - t0, 1)
        rows.append(r)
        print(r, flush=True)
    if out_path:
        doc = {"scales": rows}
        if tp is not None:
            doc["fastpath_throughput"] = tp
        with open(out_path, "w") as f:
            json.dump(doc, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
