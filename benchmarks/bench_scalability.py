"""Figure 10 analogue: accuracy under flow-concurrency / throughput scale.

Sweeps concurrent flows (and implied aggregate packet rate) through the
FENIX co-simulation (fast vectorized data plane + INT8 model engine);
reports macro-F1 of DNN-classified flows at each scale.  The paper observes
a graceful ~13% relative F1 drop at the largest (Tbps) scale — driven by
rate-limited sampling giving each flow fewer/staler inference windows —
which is exactly the mechanism simulated here.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from benchmarks._io import write_json_atomic
from repro.baselines.common import flow_vote, macro_f1
from repro.configs.fenix_models import fenix_cnn
from repro.core.fenix import FenixConfig, FenixSystem
from repro.core.data_engine.state import EngineConfig
from repro.core.model_engine.inference import ByLenModel, EngineModel
from repro.data.synthetic_traffic import (make_flows, packet_stream,
                                          windows_from_flows)
from repro.models import traffic
from repro.quant.quantize import quantize_traffic
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig, batch_iterator


def throughput(batch_size: int = 4096, n_batches: int = 12,
               seed: int = 0, include_dense: bool = True,
               include_pallas: bool = True) -> Dict:
    """Data-plane packets/sec of ``process_batch_fast`` at ``batch_size``.

    Compares the O(n log n) sort/segment admission path against the seed's
    O(n^2) dense backlog count (``dense_backlog=True``) and the Pallas
    rate-gate backend (interpret mode on CPU).  The acceptance bar for the
    device-resident fast path is >= 3x pps over the dense seed path at
    batch_size=4096 on CPU.
    """
    import jax

    from repro.core.data_engine import engine as de
    from repro.core.data_engine.state import init_state, make_packets

    rng = np.random.default_rng(seed)
    pk = make_packets(rng, batch_size)
    jb = {k: jnp.asarray(v) for k, v in pk.items()}
    modes = [("segment", EngineConfig())]
    if include_pallas:
        modes.append(("pallas_gate", EngineConfig(gate_backend="pallas")))
    if include_dense:
        modes.append(("dense_seed", EngineConfig(dense_backlog=True)))
    res: Dict = {"batch_size": batch_size}
    for name, ecfg in modes:
        state = init_state(ecfg)
        state, out = de.process_batch_fast(state, dict(jb), ecfg)
        jax.block_until_ready(out["granted"])          # compile
        t0 = time.perf_counter()
        for _ in range(n_batches):
            state, out = de.process_batch_fast(state, dict(jb), ecfg)
        jax.block_until_ready(out["granted"])
        dt = (time.perf_counter() - t0) / n_batches
        res[name] = {"us_per_batch": dt * 1e6, "pps": batch_size / dt}
    if include_dense:
        res["speedup_vs_dense"] = (res["segment"]["pps"]
                                   / res["dense_seed"]["pps"])
    return res


# the sweeps use the shared deterministic ByLenModel so they time the
# sharded data plane + merge, not DNN FLOPs

def _balanced_stream(num_pipes: int, per_pipe: int, seed: int) -> Dict:
    """Synthetic packet stream with exactly ``per_pipe`` packets per pipe.

    Random 5-tuples are generated with ~50% headroom, then trimmed so every
    pipeline owns exactly ``per_pipe`` packets (ECMP-balanced ingress) —
    the sweep measures the steady-state sharded scan, not skew tails.
    """
    from repro.core.data_engine.state import (EngineConfig, hash_five_tuple,
                                              make_packets, pipe_of_hash)
    import jax.numpy as _jnp

    rng = np.random.default_rng(seed)
    n = num_pipes * per_pipe
    over = n + n // 2 + 4096
    pk = make_packets(rng, over)
    pk["ts_us"] = np.sort(rng.integers(0, n * 10, over)).astype(np.int32)
    h = np.asarray(hash_five_tuple(
        _jnp.asarray(pk["src_ip"]), _jnp.asarray(pk["dst_ip"]),
        _jnp.asarray(pk["src_port"]), _jnp.asarray(pk["dst_port"]),
        _jnp.asarray(pk["proto"])))
    pipe = pipe_of_hash(h, EngineConfig(), num_pipes)
    keep = np.zeros(over, bool)
    for p in range(num_pipes):
        mine = np.flatnonzero(pipe == p)
        if len(mine) < per_pipe:
            raise ValueError("headroom too small for balanced trim")
        keep[mine[:per_pipe]] = True
    return {k: v[keep] for k, v in pk.items()}


def pipes_sweep(batch_sizes=(4096, 8192), pipes=(1, 2, 4),
                n_steps: int = 8, seed: int = 0) -> List[Dict]:
    """Multi-pipeline throughput: pps at num_pipes x per-pipe batch size.

    Each pipeline ingests ``batch_size`` packets per step (its own line
    rate), so a P-pipe run pushes P x batch_size x n_steps packets through
    the sharded ``run_trace`` driver; ``num_pipes=1`` is the unsharded
    device driver the acceptance bar compares against.  One warm run
    compiles, a second (after ``reset()``) is timed.
    """
    import time as _time

    from repro.core.data_engine.state import EngineConfig
    from repro.core.fenix import FenixConfig, FenixSystem
    from repro.core.model_engine.vector_io import IOConfig

    rows: List[Dict] = []
    for bs in batch_sizes:
        base_pps = None
        for p in pipes:
            n = p * bs * n_steps
            pk = _balanced_stream(p, bs * n_steps, seed)
            sys_ = FenixSystem(
                FenixConfig(engine=EngineConfig(),
                            io=IOConfig(serve_max=128),
                            batch_size=bs, control_plane_every=10**9,
                            num_pipes=p), ByLenModel())
            sys_.run_trace(pk)                     # compile + warm
            sys_.reset()
            t0 = _time.perf_counter()
            sys_.run_trace(pk)
            dt = _time.perf_counter() - t0
            row = {"num_pipes": p, "batch_size": bs, "packets": n,
                   "pps": n / dt, "wall_s": round(dt, 3),
                   "devices": min(p, len(__import__("jax").devices())),
                   "sharded": sys_._mesh is not None}
            if base_pps is None:        # first pipe count is the baseline
                base_pps, base_p = row["pps"], p
            row["baseline_pipes"] = base_p
            row["speedup_vs_1pipe"] = row["pps"] / base_pps
            rows.append(row)
            print(row, flush=True)
    return rows


def engines_sweep(engines=(1, 2, 4), batch_size: int = 64,
                  n_steps: int = 512, n_flows: int = 256,
                  oversub: float = 8.0, seed: int = 0) -> List[Dict]:
    """Model-Engine farm scale-out: served inferences/s at E engines.

    The stream oversubscribes one engine ``oversub``-fold and the
    admission gate is saturated (P=1 LUT via ``n_est=q_est=0``), so the
    token bucket holds the switch at exactly the farm's pooled service
    rate and the measurement is service-bound: served inferences per
    *simulated* second should scale linearly in E.  ``batch_size`` stays
    at/below ``EngineConfig.queue_len`` so the fast-path bucket is exact
    across batches (no within-batch credit wall).  ROADMAP success bar:
    E=2 >= 1.7x E=1; results land in benchmarks/results/engines.json.
    """
    import time as _time

    from repro.core.data_engine.state import EngineConfig
    from repro.core.fenix import FenixConfig, FenixSystem
    from repro.core.model_engine.inference import CycleModel
    from repro.core.model_engine.vector_io import IOConfig
    from repro.configs.fenix_models import fenix_cnn

    from repro.data.synthetic_traffic import uniform_flow_stream

    n = batch_size * n_steps
    pk = uniform_flow_stream(n, n_flows, seed=seed)
    span_us = max(int(pk["ts_us"][-1] - pk["ts_us"][0]), 1)
    offered_pps = n / (span_us / 1e6)
    fpga_hz = offered_pps / max(oversub, 1e-6)   # single-engine V
    cyc = CycleModel()
    rows: List[Dict] = []
    base_rate = None
    for e in engines:
        sys_ = FenixSystem(FenixConfig(
            engine=EngineConfig(fpga_hz=fpga_hz),
            io=IOConfig(queue_len=256),
            batch_size=batch_size, control_plane_every=10**9,
            num_engines=e, driver="farm"), ByLenModel(),
            n_est=0.0, q_est_pps=0.0)
        sys_.run_trace(pk)                     # compile + warm
        sys_.reset()
        t0 = _time.perf_counter()
        sys_.run_trace(pk)
        dt = _time.perf_counter() - t0
        served = sys_.stats["inferences"]
        rate = served / (span_us / 1e6)
        if base_rate is None:       # first engine count is the baseline
            base_rate, base_e = max(rate, 1e-9), e
        row = {"num_engines": e, "packets": n, "offered_pps": offered_pps,
               "oversub": oversub, "served": served,
               "served_inf_per_s": rate,
               "baseline_engines": base_e,
               "speedup_vs_1eng": rate / base_rate,
               "served_per_engine": sys_.stats["served_per_engine"],
               "granted": sys_.stats["granted"],
               "dropped_eq": sys_.stats["dropped_eq"],
               "engine_q_depth_hist": sys_.stats["engine_q_depth_hist"],
               "pps_wall": n / dt, "wall_s": round(dt, 3),
               "sharded": sys_._mesh is not None,
               # cycle-model crosscheck: modelled aggregate service rate
               "cycle_model_inf_per_s":
                   cyc.farm_throughput_inf_per_s(fenix_cnn(7), e)}
        rows.append(row)
        print(row, flush=True)
    return rows


def oversub_sweep(batch_size: int = 8192,
                  oversubs=(0.5, 4.0, 16.0, 64.0), n_flows: int = 1000,
                  pkts: int = 60_000, train_steps: int = 300,
                  train_flows: int = 400, seed: int = 1) -> Dict:
    """Figure-10 analogue at batch 8192 (ROADMAP item).

    Sweeps offered load past the Model Engine's service capacity with the
    segment admission path and the trained INT8 model: tracks macro-F1 of
    DNN-classified flows, grant fraction, and data-plane pps at each
    oversubscription factor.  The paper's observation — a graceful
    relative F1 drop as rate-limited sampling gives each flow fewer and
    staler windows — is the mechanism measured here, now at the 8192
    device-path batch size.
    """
    cfg, qp = train_model(seed=0, steps=train_steps, n_flows=train_flows)
    rows: List[Dict] = []
    for o in oversubs:
        t0 = time.time()
        r = run_scale(cfg, qp, n_flows, pkts=pkts, seed=seed, oversub=o,
                      batch_size=batch_size)
        r["wall_s"] = round(time.time() - t0, 1)
        rows.append(r)
        print(r, flush=True)
    f1_0 = max(rows[0]["macro_f1"], 1e-9)
    return {"batch_size": batch_size, "rows": rows,
            "rel_f1_drop": (f1_0 - rows[-1]["macro_f1"]) / f1_0}


def train_model(seed=0, steps=300, n_flows=400):
    flows = make_flows("iscx", n_flows, seed=seed, min_per_class=20)
    x, y, _ = windows_from_flows(flows)
    cfg = fenix_cnn(7)
    params = traffic.init(cfg, seed)
    t = Trainer(lambda p, b: traffic.loss_fn(p, cfg, b), params,
                TrainerConfig(total_steps=steps, log_every=10**9,
                              opt=OptConfig(lr=3e-3,
                                            warmup_steps=steps // 10,
                                            total_steps=steps)))
    t.run(batch_iterator(x, y, 256))
    qp = quantize_traffic(t.params, cfg, jnp.asarray(x[:512]))
    return cfg, qp


def run_scale(cfg, qp, n_flows: int, pkts: int = 60_000,
              seed: int = 1, oversub: float = 1.0,
              batch_size: int = 512) -> Dict:
    """oversub = aggregate packet rate / Model-Engine service rate V.

    This is Figure 10's x-axis: the paper pushes traffic past the FPGA's
    capacity (1000 Mpps offered vs 75 Mpps served ~ 13x); we set the
    engine's service rate so the same ratio holds at simulation scale.
    """
    flows = make_flows("iscx", n_flows, seed=seed, min_per_class=10,
                       duration_s=10.0)
    stream = packet_stream(flows, limit=pkts)
    span_us = max(int(stream["ts_us"][-1] - stream["ts_us"][0]), 1)
    pps = pkts / (span_us / 1e6)
    fpga_hz = max(pps / max(oversub, 1e-6), 1.0)
    oracle = [np.stack([f.pkt_len, f.ipd_us], -1).astype(np.int32)
              for f in flows]
    model = EngineModel(cfg, qp)
    # keep the control-plane cadence roughly constant in *simulated time*
    # across batch sizes (the default 8 x 512-packet batches): large-batch
    # runs would otherwise never rebuild the LUT from observed (N, Q) and
    # the probability gate would stay on its initial estimates
    cpe = max(1, round(8 * 512 / batch_size))
    sys_ = FenixSystem(FenixConfig(
        engine=EngineConfig(
            fpga_hz=fpga_hz,
            n_slots_log2=max(12, int(np.ceil(
                np.log2(max(n_flows * 4, 2)))))),
        batch_size=batch_size, control_plane_every=cpe,
        driver="device"), model, oracle_windows=oracle)
    t0 = time.perf_counter()
    out = sys_.run_trace(stream)
    wall_s = time.perf_counter() - t0
    # flow-level macro-F1 over flows that received a DNN verdict
    v = out["verdict"]
    ok = v >= 0
    labels = stream["label"]
    fidx = stream["flow_idx"]
    if ok.sum() == 0:
        return {"n_flows": n_flows, "oversub": oversub, "macro_f1": 0.0,
                "coverage": 0.0, "granted": sys_.stats["granted"],
                "grant_frac": sys_.stats["granted"] / pkts,
                "inferences": sys_.stats["inferences"],
                "batch_size": batch_size, "offered_pps": pps,
                "pps_wall": pkts / max(wall_s, 1e-9)}
    uf, votes = flow_vote(v[ok], fidx[ok])
    flow_labels = np.asarray([labels[fidx == f][0] for f in uf])
    f1 = macro_f1(flow_labels, votes, 7)
    return {"n_flows": n_flows, "oversub": oversub, "macro_f1": f1,
            "coverage": float(ok.mean()),
            "granted": sys_.stats["granted"],
            "grant_frac": sys_.stats["granted"] / pkts,
            "inferences": sys_.stats["inferences"],
            "batch_size": batch_size, "offered_pps": pps,
            "pps_wall": pkts / max(wall_s, 1e-9)}


def main(out_path: str = None,
         scales=((1000, 0.5), (1000, 4.0), (1000, 16.0), (1000, 64.0),
                 (4000, 16.0), (8000, 16.0)),
         include_throughput: bool = True) -> List:
    # run.py measures throughput as its own row; it passes
    # include_throughput=False here to avoid paying for the sweep twice
    tp = throughput() if include_throughput else None
    if tp is not None:
        print({"fastpath": tp}, flush=True)
    cfg, qp = train_model()
    rows = []
    for n, oversub in scales:
        t0 = time.time()
        r = run_scale(cfg, qp, n, oversub=oversub)
        r["wall_s"] = round(time.time() - t0, 1)
        rows.append(r)
        print(r, flush=True)
    if out_path:
        doc = {"scales": rows}
        if tp is not None:
            doc["fastpath_throughput"] = tp
        write_json_atomic(out_path, doc)
    return rows


if __name__ == "__main__":
    main()
