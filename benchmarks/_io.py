"""Atomic JSON artifact writes for the benchmark harness.

Benchmark sections write to ``benchmarks/results/*.json`` which CI uploads
as artifacts and the regression gate diffs; a section that crashes mid-dump
must not leave a truncated file behind.  ``write_json_atomic`` writes to a
temp file in the destination directory (created if missing) and renames it
into place — rename is atomic on POSIX, so readers only ever see the old or
the new complete document.
"""

from __future__ import annotations

import json
import os
import tempfile


def write_json_atomic(path, obj, indent: int = 1, default=None) -> None:
    path = os.fspath(path)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(
        dir=d, prefix=".tmp-", suffix=os.path.basename(path)
    )
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(obj, f, indent=indent, default=default)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
