"""Admission-gate microbenchmark: fused vs unfused (``--only gate``).

Times ONLY the admission phase (LUT probability + threshold draw + token
-bucket credit check) at trace-driver batch sizes, in three arrangements:

  unfused   the pre-fusion arrangement: the LUT lookup computed as a
            separate one-hot matmul beside the admission math (exactly
            what ``gate_backend="pallas"`` used to evaluate per chunk —
            the ``rate_gate`` kernel's contraction — followed by the
            stand-alone bucket ops)
  fused     one ``fused_admission`` call per chunk (ref backend: the
            gather folded into the admission computation, the graph the
            compiled-TPU kernel mirrors)
  fused_pallas_us
            the fused Pallas kernel in interpret mode — the correctness
            / lowering path, reported for visibility (interpret mode is
            NOT a CPU performance path)

Sweep: batch {4096, 8192} x pipes {1, 2} (pipes > 1 runs the per-pipe
admission under vmap, the sharded driver's fallback form).  Writes
``benchmarks/results/gate.json``; the acceptance bar is fused >= 1.2x
unfused at batch 8192.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.probability import LUTConfig, build_lut
from repro.kernels.rate_gate.ops import fused_admission

I32 = jnp.int32
LCFG = LUTConfig()
COST_US = 4
CAP_US = 64 * COST_US


def _onehot_lookup(t_i, c_i, lut):
    """The unfused LUT gather: one-hot matmul beside the scan (the exact
    contraction the selection-only kernel ran as a separate stage)."""
    tb, cb = lut.shape
    n = t_i.shape[0]
    ti = jnp.clip(t_i >> LCFG.t_shift, 0, tb - 1)
    ci = jnp.clip(c_i >> LCFG.c_shift, 0, cb - 1)
    rows = jax.lax.broadcasted_iota(I32, (n, tb), 1)
    onehot_t = (rows == ti[:, None]).astype(jnp.float32)
    lut_rows = jax.lax.dot_general(
        onehot_t, lut.astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    cols = jax.lax.broadcasted_iota(I32, (n, cb), 1)
    onehot_c = (cols == ci[:, None]).astype(jnp.float32)
    return jnp.sum(lut_rows * onehot_c, axis=-1).astype(I32)


def _unfused(t_i, c_i, ts, rand, lut, bucket, t_last):
    prob = _onehot_lookup(t_i, c_i, lut)
    selected = rand < prob
    t_ref = jnp.where(t_last == 0, ts[0], t_last)
    credit = jnp.minimum(bucket, CAP_US) + jnp.maximum(ts - t_ref, 0)
    spend = jnp.cumsum(jnp.where(selected, COST_US, 0))
    granted = selected & (spend <= credit)
    bucket_new = jnp.clip(
        credit[-1] - jnp.sum(granted.astype(I32)) * COST_US, 0, CAP_US)
    return granted, bucket_new.astype(I32)


def _fused(t_i, c_i, ts, rand, lut, bucket, t_last, backend="ref"):
    return fused_admission(t_i, c_i, ts, lut, bucket, t_last, rand16=rand,
                           cost_us=COST_US, bucket_cap_us=CAP_US,
                           t_shift=LCFG.t_shift, c_shift=LCFG.c_shift,
                           prob_bits=LCFG.prob_bits, backend=backend)


def _args(batch: int, pipes: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    shape = (pipes, batch) if pipes > 1 else (batch,)
    t_i = jnp.asarray(rng.integers(0, 1 << 17, shape), I32)
    c_i = jnp.asarray(rng.integers(0, 128, shape), I32)
    ts = jnp.asarray(np.sort(rng.integers(0, 1 << 20, shape), axis=-1),
                     I32)
    rand = jnp.asarray(rng.integers(0, 1 << LCFG.prob_bits, shape), I32)
    lut = jnp.asarray(build_lut(n=800, q=1.0, v=0.05, cfg=LCFG))
    if pipes > 1:
        lut = jnp.stack([lut] * pipes)
        bucket = jnp.full((pipes,), CAP_US // 2, I32)
        t_last = jnp.zeros((pipes,), I32)
    else:
        bucket = jnp.asarray(CAP_US // 2, I32)
        t_last = jnp.asarray(0, I32)
    return t_i, c_i, ts, rand, lut, bucket, t_last


def _time(fn, args, iters: int) -> float:
    out = fn(*args)
    jax.block_until_ready(out)              # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def sweep(batch_sizes=(4096, 8192), pipes=(1, 2), iters: int = 50,
          interp_iters: int = 3) -> Dict:
    """One row per (batch, pipes) cell; fused/unfused identical outputs
    are asserted before anything is timed."""
    rows: List[Dict] = []
    for p in pipes:
        un = jax.jit(jax.vmap(_unfused) if p > 1 else _unfused)
        fu = jax.jit(jax.vmap(_fused) if p > 1 else _fused)
        fu_pal = jax.jit(
            jax.vmap(lambda *a: _fused(*a, backend="pallas")) if p > 1
            else (lambda *a: _fused(*a, backend="pallas")))
        for b in batch_sizes:
            args = _args(b, p)
            g_un, b_un = un(*args)
            g_fu, b_fu = fu(*args)
            assert bool(jnp.all(g_un == g_fu)) and \
                bool(jnp.all(b_un == b_fu)), "fused != unfused admission"
            us_un = _time(un, args, iters)
            us_fu = _time(fu, args, iters)
            us_pal = _time(fu_pal, args, interp_iters)
            rows.append({
                "batch_size": b, "num_pipes": p,
                "unfused_us": round(us_un, 2),
                "fused_us": round(us_fu, 2),
                "fused_pallas_interpret_us": round(us_pal, 2),
                "speedup_fused": round(us_un / us_fu, 3),
                "granted": int(jnp.sum(g_fu.astype(I32))),
            })
    at_8192 = [r for r in rows if r["batch_size"] == 8192
               and r["num_pipes"] == 1]
    return {
        "cost_us": COST_US, "bucket_cap_us": CAP_US,
        "lut_bins": [LCFG.t_bins, LCFG.c_bins],
        "rows": rows,
        "speedup_at_8192": at_8192[0]["speedup_fused"] if at_8192 else None,
        "note": "unfused = one-hot-matmul LUT lookup beside the "
                "admission ops (the pre-fusion gate_backend='pallas' "
                "graph); fused = single fused_admission call; interpret "
                "timing is the correctness path, not a CPU perf path",
    }
