"""Sustained replay soak (``--only soak``): the zero-host-sync streaming
data plane under continuous load.

One ``FenixSystem(driver="device")`` replays the pcap fixture over and
over through ``run_trace(TraceSpec(...))`` — state is NOT reset between
passes, so this measures the steady state the single-shot benchmarks
can't: compiled-cache reuse, donated-carry buffer recycling, RSS
flatness, and the in-scan control plane staying at zero host syncs no
matter how long the replay runs.

Four replay modes are timed over identical packets:

  overlap   streaming ingest, double-buffered: a producer thread parses
            and stages block k+1 while the scan consumes block k
            (``TraceSpec(overlap=True)``, the default)
  sync      same streaming ingest, synchronous staging
            (``TraceSpec(overlap=False)``) — parse and scan alternate
  fused     in-memory replay, one scan per pass with the in-scan
            control plane (the zero-host-sync data plane, parse
            excluded)
  synced-cp the same in-memory replay driven the pre-fold way: one
            scan per T_w window with a host-driven ``control_plane()``
            round trip between windows (what the in-scan ``"_cp"``
            rebuild replaced)

Reported (soak.json): per-pass pps + median steady-state pps per mode,
``overlap_speedup`` (overlap vs sync staging — on multi-core hosts the
parse hides under the scan; single-core runners can invert it since
the producer thread competes for the only core), ``zerosync_speedup``
(fused vs synced-cp, both in-memory, isolating the control-plane
fold), host-sync counts (asserted 0 for the zero-sync modes), and
per-pass VmRSS with its growth across the soak.  The regression gate
(``check_regression.py``) gates the two speedup ratios — run-relative,
so runner noise largely cancels — while absolute pps stays
informational.

Timing discipline: the first pass of every mode is an untimed warmup
(compiles both block shapes + the tail), and each timed pass ends with
``jax.block_until_ready`` on the carried state before the clock is read.

``python -m benchmarks.bench_soak [--full] [--duration S]``
"""

from __future__ import annotations

import argparse
import os
import statistics
import time
from typing import Dict, List, Optional

import jax

from benchmarks._io import write_json_atomic
from benchmarks.bench_traces import build_fixture
from repro.core.fenix import FenixConfig, FenixSystem, TraceSpec
from repro.core.model_engine.inference import ByLenModel
from repro.data import trace_ingest as ti

BATCH = 512
CPE = 3
# small chunks force multi-chunk parses per pass so there is actually
# parse work for the producer thread to hide under the scans
CHUNK_PKTS = 2048


def _rss_mb() -> float:
    with open("/proc/self/status") as f:
        for line in f:
            if line.startswith("VmRSS:"):
                return int(line.split()[1]) / 1024.0
    return 0.0


def _soak_zero_sync(trace, n_pkts: int, passes: int,
                    min_seconds: float) -> Dict:
    """Replay ``trace`` (a TraceSpec for the streaming modes, a
    packet-stream dict for the in-memory fused mode) repeatedly through
    ONE system; per-pass pps after an untimed warmup pass.  Runs at
    least ``passes`` timed passes and keeps going until ``min_seconds``
    of timed replay have elapsed."""
    sys_ = FenixSystem(FenixConfig(batch_size=BATCH,
                                   control_plane_every=CPE,
                                   driver="device"), ByLenModel())
    sys_.run_trace(trace)                      # warmup: compile everything
    jax.block_until_ready(sys_.state["lut"])
    pps: List[float] = []
    rss: List[float] = []
    t_start = time.perf_counter()
    while len(pps) < passes or \
            time.perf_counter() - t_start < min_seconds:
        t0 = time.perf_counter()
        sys_.run_trace(trace)
        jax.block_until_ready(sys_.state["lut"])
        pps.append(n_pkts / (time.perf_counter() - t0))
        rss.append(round(_rss_mb(), 1))
    assert sys_.host_syncs == 0, (
        f"zero-sync replay performed {sys_.host_syncs} host control-plane "
        "syncs; the device driver must run them in-scan")
    assert sys_.stats["packets"] == n_pkts * (len(pps) + 1)
    return {"pps_per_pass": [round(p, 1) for p in pps],
            "steady_pps": round(statistics.median(pps), 1),
            "passes": len(pps), "host_syncs": sys_.host_syncs,
            "rss_mb_per_pass": rss,
            "rss_growth_mb": round(rss[-1] - rss[0], 1) if rss else 0.0}


def _soak_synced(stream: Dict, passes: int) -> Dict:
    """The pre-fold device loop: one scan per T_w window with a
    host-driven ``control_plane()`` between windows — the host-sync
    pattern the in-scan ``"_cp"`` rebuild removed.  In-scan rollover is
    disabled (control_plane_every past the window count) so the host
    round trip is the only control plane, exactly as before."""
    win = BATCH * CPE
    n_win = len(stream["ts_us"]) // win
    windows = [{k: v[i * win:(i + 1) * win] for k, v in stream.items()}
               for i in range(n_win)]
    sys_ = FenixSystem(FenixConfig(batch_size=BATCH,
                                   control_plane_every=1 << 30,
                                   driver="device"), ByLenModel())
    sys_.run_trace(windows[0])                 # warmup
    sys_.control_plane()
    jax.block_until_ready(sys_.state["lut"])
    pps: List[float] = []
    for _ in range(passes):
        t0 = time.perf_counter()
        for w in windows:
            sys_.run_trace(w)
            sys_.control_plane()
        jax.block_until_ready(sys_.state["lut"])
        pps.append(n_win * win / (time.perf_counter() - t0))
    assert sys_.host_syncs == n_win * passes + 1
    return {"pps_per_pass": [round(p, 1) for p in pps],
            "steady_pps": round(statistics.median(pps), 1),
            "passes": passes, "host_syncs": sys_.host_syncs,
            "windows_per_pass": n_win}


def main(out_path: Optional[str] = None, fast: bool = True,
         duration: Optional[float] = None) -> Dict:
    """``--only soak`` entry point.  ``duration`` is the minimum timed
    replay per streaming mode (seconds); fast mode just runs the minimum
    pass count."""
    pcap = build_fixture()
    stream = ti.load_stream(pcap)
    n_pkts = len(stream["ts_us"])
    passes = 3 if fast else 5
    min_s = 0.0 if duration is None and fast else \
        (duration if duration is not None else 90.0)

    overlap = _soak_zero_sync(
        TraceSpec(pcap, chunk_pkts=CHUNK_PKTS, overlap=True),
        n_pkts, passes, min_s)
    sync = _soak_zero_sync(
        TraceSpec(pcap, chunk_pkts=CHUNK_PKTS, overlap=False),
        n_pkts, passes, min_s)
    # the control-plane comparison runs in-memory on both sides (parse
    # excluded) over the same window-aligned packet count
    win = BATCH * CPE
    n_trim = (n_pkts // win) * win
    trimmed = {k: v[:n_trim] for k, v in stream.items()}
    fused = _soak_zero_sync(trimmed, n_trim, max(2, passes - 1), 0.0)
    synced_cp = _soak_synced(trimmed, max(2, passes - 1))

    res = {
        "fixture": os.path.basename(pcap), "packets_per_pass": n_pkts,
        "batch_size": BATCH, "control_plane_every": CPE,
        "chunk_pkts": CHUNK_PKTS,
        "overlap": overlap, "sync_staging": sync,
        "fused": fused, "synced_control_plane": synced_cp,
        # both gated ratios are run-relative: numerator and denominator
        # come from the same process minutes apart, so machine speed
        # cancels and the gate tracks the architecture, not the runner
        "overlap_speedup": round(
            overlap["steady_pps"] / max(sync["steady_pps"], 1e-9), 3),
        "zerosync_speedup": round(
            fused["steady_pps"] / max(synced_cp["steady_pps"], 1e-9), 3),
    }
    for mode in ("overlap", "sync_staging", "fused"):
        print(f"soak_{mode}: steady_pps={res[mode]['steady_pps']:.0f} "
              f"passes={res[mode]['passes']} "
              f"host_syncs={res[mode]['host_syncs']} "
              f"rss_growth_mb={res[mode].get('rss_growth_mb', 0.0)}",
              flush=True)
    print(f"soak_synced_cp: steady_pps="
          f"{synced_cp['steady_pps']:.0f} "
          f"host_syncs={synced_cp['host_syncs']}", flush=True)
    print(f"soak: overlap_speedup={res['overlap_speedup']}x "
          f"zerosync_speedup={res['zerosync_speedup']}x", flush=True)
    if out_path:
        write_json_atomic(out_path, res)
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="multi-minute soak (5+ passes, >=90s per mode)")
    ap.add_argument("--duration", type=float, default=None,
                    help="minimum timed seconds per streaming mode")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "results", "soak.json"))
    args = ap.parse_args()
    main(out_path=args.out, fast=not args.full, duration=args.duration)
