"""Trace replay: every driver (host/device/pipes/farm) over one ingested
capture.

Builds a deterministic pcap fixture with ``synthesize_pcap`` (the same
generator CI caches), ingests it back through the streaming reader —
asserting the ``pcap -> ingest -> packet_stream`` round trip is
bit-identical to the source stream, the subsystem's correctness oracle —
then replays the ingested stream through all four trace drivers (the
capture is parsed once; each driver's wall clock times the driver, not
re-ingestion — the streaming ``run_trace(TraceSpec(...))`` path itself
is covered by examples/trace_smoke.py, tests/test_trace_ingest.py, and
bench_soak.py):

  host     batch-at-a-time reference loop (``driver="host"``)
  device   jitted single-pipe ``lax.scan``
  pipes    2-pipeline sharded driver (vmap fallback below 2 devices)
  farm     2-pipe x 2-engine Model-Engine farm

The stats dicts stay structurally comparable across drivers (same keys —
asserted), so the regression gate can diff any of them; rows land in
``benchmarks/results/traces.json``.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks._io import write_json_atomic
from repro.core.data_engine.state import EngineConfig
from repro.core.fenix import FenixConfig, FenixSystem
from repro.core.model_engine.inference import ByLenModel
from repro.data import trace_ingest as ti
from repro.data.synthetic_traffic import make_flows

FIXTURE_DIR = os.environ.get(
    "TRACE_FIXTURE_DIR",
    os.path.join(os.path.dirname(__file__), "fixtures"))

# deterministic fixture recipe — examples/trace_smoke.py and the CI cache
# key both hang off this module, so changing it regenerates fixtures
FIXTURE_TASK = "iscx"
FIXTURE_FLOWS = 220
FIXTURE_SEED = 23
FIXTURE_LIMIT = 16384


def build_fixture(fixture_dir: str = FIXTURE_DIR,
                  verify: bool = True) -> str:
    """Materialize (or reuse) the pcap fixture; returns its path.

    The generator is deterministic, so the oracle stream can be recomputed
    regardless of whether the file came from a fresh write or a CI cache
    hit — ``verify`` re-ingests and asserts bit-identity either way, which
    is what makes a cached fixture trustworthy.
    """
    os.makedirs(fixture_dir, exist_ok=True)
    pcap = os.path.join(fixture_dir,
                        f"{FIXTURE_TASK}_replay_s{FIXTURE_SEED}.pcap")
    flows = make_flows(FIXTURE_TASK, FIXTURE_FLOWS, seed=FIXTURE_SEED,
                       min_per_class=8, duration_s=10.0)
    if os.path.exists(pcap) and os.path.exists(ti.sidecar_path(pcap)):
        from repro.data.synthetic_traffic import packet_stream
        oracle = packet_stream(flows, limit=FIXTURE_LIMIT)
    else:
        oracle = ti.synthesize_pcap(flows, pcap, limit=FIXTURE_LIMIT)
    if verify:
        got = ti.ingest_pcap(pcap)
        for k in oracle:
            np.testing.assert_array_equal(
                got[k], oracle[k],
                err_msg=f"pcap round-trip diverged on {k!r} — stale or "
                        f"corrupt fixture {pcap}; delete it to rebuild")
    return pcap


def _driver_configs(batch_size: int) -> List:
    ecfg = EngineConfig()
    return [
        ("host", FenixConfig(engine=ecfg, batch_size=batch_size,
                             driver="host")),
        ("device", FenixConfig(engine=ecfg, batch_size=batch_size)),
        ("pipes", FenixConfig(engine=ecfg, batch_size=batch_size,
                              num_pipes=2)),
        ("farm", FenixConfig(engine=ecfg, batch_size=batch_size,
                             num_pipes=2, num_engines=2, driver="farm")),
    ]


def replay(stream: Dict, batch_size: int = 512) -> List[Dict]:
    """Replay one ingested stream through all four drivers; one row
    per driver (wall clock covers the driver only)."""
    rows: List[Dict] = []
    stats_keys = None
    n_probe = len(stream["ts_us"])
    for name, cfg in _driver_configs(batch_size):
        sys_ = FenixSystem(cfg, ByLenModel())
        t0 = time.perf_counter()
        out = sys_.run_trace(stream)
        wall = time.perf_counter() - t0
        st = sys_.stats
        if stats_keys is None:
            stats_keys = sorted(st)
        assert sorted(st) == stats_keys, (
            f"driver {name} stats keys diverge: {sorted(st)} vs "
            f"{stats_keys}")
        v = out["verdict"]
        rows.append({
            "driver": name, "packets": int(st["packets"]),
            "wall_s": round(wall, 3),
            "pps_wall": st["packets"] / max(wall, 1e-9),
            "granted": int(st["granted"]),
            "inferences": int(st["inferences"]),
            "classified_frac": float((v >= 0).mean()) if len(v) else 0.0,
            "dropped_q": int(st["dropped_q"]),
            "served_per_engine": list(st["served_per_engine"]),
            "num_pipes": cfg.num_pipes, "num_engines": cfg.num_engines,
        })
        assert rows[-1]["packets"] == n_probe
        print(rows[-1], flush=True)
    return rows


def main(out_path: Optional[str] = None, fast: bool = True,
         source: Optional[str] = None,
         adapter: Optional[str] = None) -> Dict:
    """``--only traces`` entry point.

    ``source`` replays a user-supplied capture (pcap or CSV via
    ``adapter``) instead of the synthesized fixture.
    """
    pcap = build_fixture() if source is None else source
    limit = 6144 if fast else None
    # parse the capture exactly once; drivers replay the in-memory stream
    stream = ti.load_stream(pcap, adapter=adapter, limit=limit)
    # served inferences per *simulated* second — machine-independent, the
    # regression gate's stable rate metric
    span_us = max(int(stream["ts_us"].max() - stream["ts_us"].min()), 1)
    rows = replay(stream)
    for r in rows:
        r["served_inf_per_s"] = r["inferences"] / (span_us / 1e6)
    res = {"source": os.path.basename(str(pcap)), "limit": limit,
           "span_us": span_us, "rows": rows}
    if out_path:
        write_json_atomic(out_path, res)
    return res


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--source", default=None,
                    help="capture to replay (default: synthesized fixture)")
    ap.add_argument("--adapter", default=None,
                    help="CSV schema adapter for --source")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "results", "traces.json"))
    args = ap.parse_args()
    main(out_path=args.out, fast=not args.full, source=args.source,
         adapter=args.adapter)
