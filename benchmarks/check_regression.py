"""Benchmark regression gate: current ``--fast`` results vs the committed
baseline.

CI runs ``python -m benchmarks.run --fast --only <gated sections>`` and
then this module.  Every metric present in ``benchmarks/results/baseline/``
is compared against the freshly written ``benchmarks/results/`` document:

  rate metrics (pps, served inferences/s)  fail when they drop more than
                                           ``--pps-tol`` (default 20%)
  f1 metrics (macro-F1)                    fail when they drop more than
                                           ``--f1-tol`` (default 0.05)
                                           absolute
  point metrics (test coverage %)          fail when they drop more than
                                           ``--cov-tol`` (default 5.0)
                                           points absolute — the soft
                                           coverage floor.  Gated (and
                                           rebaselined) ONLY when named:
                                           ``--files coverage.json``
                                           [--rebaseline]; the default
                                           run covers the benchmark
                                           files only, since coverage
                                           comes from the pytest --cov
                                           CI leg, not benchmarks.run

A diff summary (metric, baseline, current, delta, verdict) is printed to
the job log either way; the exit code gates the build.  Metrics/files in
the baseline but missing from the current run fail; extra current metrics
are ignored (so adding benchmarks never requires touching the gate).

Wall-clock rates vary with runner hardware — re-baseline with
``python -m benchmarks.check_regression --rebaseline`` after intentional
performance changes (copies the gated result files over the baseline), and
tune ``--pps-tol`` (or the ``REGRESSION_PPS_TOL`` env var) if CI runners
are noisier than 20%.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from typing import Dict, List, Optional, Tuple

RESULTS = os.path.join(os.path.dirname(__file__), "results")
BASELINE = os.path.join(RESULTS, "baseline")

# metric kinds: "rate" -> relative-drop gate, "f1" -> absolute-drop gate
Metric = Tuple[str, str, float]


def _metrics_throughput(doc) -> List[Metric]:
    return [("segment_pps", "rate", doc["segment"]["pps"])]


def _metrics_engines(doc) -> List[Metric]:
    return [(f"e{r['num_engines']}_served_inf_per_s", "rate",
             r["served_inf_per_s"]) for r in doc["rows"]]


def _metrics_traces(doc) -> List[Metric]:
    # gate the *simulated* service rate only: it is machine-independent
    # and bit-stable run to run.  Per-driver wall-clock pps at --fast
    # packet counts swings far more than 20% with runner load (observed
    # -36% on the host python-loop driver between back-to-back runs on
    # the same box), so it stays informational in traces.json.
    return [(f"{r['driver']}_served_inf_per_s", "rate",
             r["served_inf_per_s"]) for r in doc["rows"]]


def _metrics_soak(doc) -> List[Metric]:
    # gate the zero-sync ratio only: fused and synced-cp replay the same
    # in-memory stream in the same process, so runner speed cancels and
    # the gate tracks the in-scan control-plane fold itself (observed
    # ~2x, stable within ~15% across back-to-back runs).
    # overlap_speedup stays informational: ingest overlap needs a spare
    # core for the producer thread, so on single-core runners the ratio
    # is scheduler noise (observed 0.8x-1.5x back to back on the same
    # box) — soak.json still reports it for multi-core hosts.  Absolute
    # steady_pps is informational too (see _metrics_traces).
    return [("zerosync_speedup", "rate", doc["zerosync_speedup"])]


def _metrics_accuracy(doc) -> List[Metric]:
    # extract ONLY numeric macro_f1 leaves: scheme dicts carry extra
    # artifact keys (per-class "confusion" matrices, "_classes" legends,
    # "_wall_s" timings) that are documentation, not gated metrics —
    # anything that is not a {"macro_f1": <number>} entry is skipped so
    # adding artifact detail never breaks the gate
    out: List[Metric] = []
    for task, schemes in doc.items():
        if not isinstance(schemes, dict):
            continue
        for name, res in schemes.items():
            if isinstance(res, dict) and \
                    isinstance(res.get("macro_f1"), (int, float)):
                out.append((f"{task}/{name}", "f1", res["macro_f1"]))
    return out


def _metrics_coverage(doc) -> List[Metric]:
    # accepts both the raw coverage.py JSON report ({"totals":
    # {"percent_covered": X}}) and a hand-rolled {"percent_covered": X}
    pct = doc.get("totals", doc).get("percent_covered")
    return [] if pct is None else [("percent_covered", "points",
                                    float(pct))]


EXTRACTORS = {
    "throughput.json": _metrics_throughput,
    "engines.json": _metrics_engines,
    "traces.json": _metrics_traces,
    "soak.json": _metrics_soak,
    "accuracy.json": _metrics_accuracy,
    "coverage.json": _metrics_coverage,
}

# gated / rebaselined ONLY when named via --files: coverage.json is
# produced by the pytest --cov CI leg, never by benchmarks.run, so the
# default invocation (after a benchmark run) must neither fail on its
# absence nor clobber its committed floor with a stale local report
EXPLICIT_ONLY = {"coverage.json"}


def _load(path):
    with open(path) as f:
        return json.load(f)


def compare(results_dir: str = RESULTS, baseline_dir: str = BASELINE,
            pps_tol: float = 0.20, f1_tol: float = 0.05,
            cov_tol: float = 5.0, files: Optional[List[str]] = None
            ) -> Tuple[List[Dict], int]:
    """-> (rows, n_failures).  One row per gated metric.  ``files``
    restricts the gate to a subset of result files (the coverage gate
    runs in a job that produces only coverage.json); the default set
    excludes the EXPLICIT_ONLY files."""
    rows: List[Dict] = []
    failures = 0
    for fname, extract in sorted(EXTRACTORS.items()):
        if (fname not in files) if files is not None \
                else (fname in EXPLICIT_ONLY):
            continue
        base_path = os.path.join(baseline_dir, fname)
        if not os.path.exists(base_path):
            continue                       # nothing committed: not gated
        cur_path = os.path.join(results_dir, fname)
        if not os.path.exists(cur_path):
            rows.append({"metric": fname, "baseline": "present",
                         "current": "MISSING", "delta": "",
                         "status": "FAIL"})
            failures += 1
            continue
        base = dict((m[0], m) for m in extract(_load(base_path)))
        cur = dict((m[0], m) for m in extract(_load(cur_path)))
        for name, (_, kind, bval) in sorted(base.items()):
            tag = f"{fname.removesuffix('.json')}/{name}"
            if name not in cur:
                rows.append({"metric": tag, "baseline": f"{bval:.4g}",
                             "current": "MISSING", "delta": "",
                             "status": "FAIL"})
                failures += 1
                continue
            cval = cur[name][2]
            if kind == "rate":
                drop = (bval - cval) / max(bval, 1e-12)
                ok = drop <= pps_tol
                delta = f"{-drop:+.1%}"
            elif kind == "points":
                drop = bval - cval
                ok = drop <= cov_tol
                delta = f"{-drop:+.1f}pt"
            else:
                drop = bval - cval
                ok = drop <= f1_tol
                delta = f"{-drop:+.4f}"
            rows.append({"metric": tag, "baseline": f"{bval:.4g}",
                         "current": f"{cval:.4g}", "delta": delta,
                         "status": "ok" if ok else "FAIL"})
            failures += 0 if ok else 1
    return rows, failures


def rebaseline(results_dir: str = RESULTS, baseline_dir: str = BASELINE,
               files: Optional[List[str]] = None) -> None:
    """Copy current gated results over the baseline — honoring the same
    ``--files`` subset as the gate, and never touching an EXPLICIT_ONLY
    baseline (e.g. the coverage floor) unless it is named."""
    os.makedirs(baseline_dir, exist_ok=True)
    for fname in EXTRACTORS:
        if (fname not in files) if files is not None \
                else (fname in EXPLICIT_ONLY):
            continue
        src = os.path.join(results_dir, fname)
        if os.path.exists(src):
            shutil.copyfile(src, os.path.join(baseline_dir, fname))
            print(f"rebaselined {fname}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results", default=RESULTS)
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--pps-tol", type=float, default=float(
        os.environ.get("REGRESSION_PPS_TOL", 0.20)),
        help="max relative drop for rate metrics (default 0.20)")
    ap.add_argument("--f1-tol", type=float, default=float(
        os.environ.get("REGRESSION_F1_TOL", 0.05)),
        help="max absolute drop for macro-F1 metrics (default 0.05)")
    ap.add_argument("--cov-tol", type=float, default=float(
        os.environ.get("REGRESSION_COV_TOL", 5.0)),
        help="max absolute drop (points) for coverage (default 5.0)")
    ap.add_argument("--files", default=None,
                    help="comma-separated subset of result files to gate "
                         "(e.g. coverage.json); default: all committed")
    ap.add_argument("--rebaseline", action="store_true",
                    help="copy current gated results over the baseline")
    args = ap.parse_args(argv)
    file_subset = None
    if args.files:
        file_subset = [f.strip() for f in args.files.split(",") if f.strip()]
        unknown = sorted(set(file_subset) - set(EXTRACTORS))
        if unknown:
            # a typo'd --files would otherwise gate nothing and exit 0
            ap.error(f"unknown --files entr{'ies' if len(unknown) > 1 else 'y'}: "
                     f"{', '.join(unknown)}; known: "
                     f"{', '.join(sorted(EXTRACTORS))}")
    if args.rebaseline:
        rebaseline(args.results, args.baseline, files=file_subset)
        return 0
    rows, failures = compare(args.results, args.baseline,
                             pps_tol=args.pps_tol, f1_tol=args.f1_tol,
                             cov_tol=args.cov_tol, files=file_subset)
    if not rows:
        print(f"no baseline files under {args.baseline}; nothing gated")
        return 0
    widths = [max(len(str(r[k])) for r in rows + [
        {"metric": "metric", "baseline": "baseline", "current": "current",
         "delta": "delta", "status": "status"}])
        for k in ("metric", "baseline", "current", "delta", "status")]
    fmt = ("{:<%d}  {:>%d}  {:>%d}  {:>%d}  {:<%d}" % tuple(widths))
    print(fmt.format("metric", "baseline", "current", "delta", "status"))
    for r in rows:
        print(fmt.format(r["metric"], r["baseline"], r["current"],
                         r["delta"], r["status"]))
    n = len(rows)
    if failures:
        print(f"\nREGRESSION: {failures}/{n} gated metrics failed "
              f"(rate tol {args.pps_tol:.0%}, f1 tol {args.f1_tol})")
        return 1
    print(f"\nall {n} gated metrics within tolerance "
          f"(rate tol {args.pps_tol:.0%}, f1 tol {args.f1_tol})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
