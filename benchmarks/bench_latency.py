"""Figure 11 analogue: latency breakdown, FENIX vs control-plane path.

Components (paper): internal transmission (PCB, sub-us), external
transmission (optical, 1-3us), inference (FENIX 1.2us FPGA vs FlowLens
>1000us CPU).  We report:
  - the FPGA cycle-model latency of our INT8 models (ZU19EG-like array)
  - the TPU-v5e roofline latency of the same window batch (Pallas kernel)
  - measured CPU wall-time per inference (this container, for reference),
    for BOTH the served INT8 integer path (kernels/int8_matmul, "ref"
    backend) and the float parent model — the int8-vs-float serving
    comparison of the Fig. 11 analogue
  - engine-farm service latency of a 128-window batch at E in {1, 2, 4}
  - the control-plane path modeled with the paper's measured RTTs.
"""

from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._io import write_json_atomic
from repro.configs.fenix_models import fenix_cnn, fenix_rnn
from repro.core.model_engine.inference import (CycleModel, EngineModel,
                                               macs_per_inference,
                                               tpu_latency_us)
from repro.data.synthetic_traffic import make_flows, windows_from_flows
from repro.models import traffic
from repro.quant.quantize import quantize_traffic

# paper Figure 11 measurements (for the comparison rows)
PAPER = {
    "fenix": {"internal_us": 0.8, "external_us": 2.0, "inference_us": 1.2},
    "flowlens": {"transmission_us": 2100.0, "inference_us": 1500.0},
}


def main(out_path: str = None) -> Dict:
    flows = make_flows("iscx", 60, seed=0)
    x, _, _ = windows_from_flows(flows)
    out: Dict[str, Dict] = {"paper_fig11": PAPER}
    cm = CycleModel()
    for mk in (fenix_cnn, fenix_rnn):
        cfg = mk(7)
        params = traffic.init(cfg, 0)
        qp = quantize_traffic(params, cfg, jnp.asarray(x[:128]))
        model = EngineModel(cfg, qp)
        batch = jnp.asarray(x[:128])
        model.infer(batch)  # warm up / compile
        t0 = time.time()
        reps = 20
        for _ in range(reps):
            r = model.infer(batch)
        jax.block_until_ready(r)
        cpu_us = (time.time() - t0) / reps / batch.shape[0] * 1e6
        # float parent model on the same batch: what serving would cost
        # without quantization (per-inference wall time, this container)
        float_fn = jax.jit(lambda p, b: jnp.argmax(
            traffic.apply(p, cfg, b), -1))
        jax.block_until_ready(float_fn(params, batch))
        t0 = time.time()
        for _ in range(reps):
            r = float_fn(params, batch)
        jax.block_until_ready(r)
        float_us = (time.time() - t0) / reps / batch.shape[0] * 1e6
        # engine-farm service: the same 128-window batch split across E
        # engines (cycle model) and the fused multi-engine inference pass
        # (one infer_engines call serving every engine's lanes at once)
        farm_batch = jnp.asarray(x[:128]).reshape(4, 32, *x.shape[1:])
        fused = model.infer_engines(farm_batch)
        np.testing.assert_array_equal(np.asarray(fused).reshape(-1),
                                      np.asarray(model.infer(batch)))
        t0 = time.time()
        for _ in range(reps):
            r = model.infer_engines(farm_batch)
        jax.block_until_ready(r)
        fused_us = (time.time() - t0) / reps / batch.shape[0] * 1e6
        out[cfg.name] = {
            "macs_per_window": macs_per_inference(cfg),
            "fpga_cycle_model_us": cm.latency_us(cfg),
            "fpga_throughput_inf_s": cm.throughput_inf_per_s(cfg),
            "farm_batch128_us": {
                e: cm.farm_batch_latency_us(cfg, 128, e)
                for e in (1, 2, 4)},
            "farm4_fused_cpu_us_per_inf": fused_us,
            "tpu_roofline": tpu_latency_us(cfg, batch=128),
            "cpu_measured_us_per_inf": cpu_us,
            "float_cpu_us_per_inf": float_us,
            "int8_vs_float_cpu_ratio": cpu_us / max(float_us, 1e-9),
            "speedup_vs_control_plane":
                (PAPER["flowlens"]["transmission_us"]
                 + PAPER["flowlens"]["inference_us"])
                / (PAPER["fenix"]["external_us"] + cm.latency_us(cfg)),
        }
    if out_path:
        write_json_atomic(out_path, out)
    return out


if __name__ == "__main__":
    import pprint
    pprint.pprint(main())
